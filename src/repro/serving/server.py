"""The Clairvoyant sidecar: features -> predictor -> SJF queue -> engine.

This is the paper's Figure 2 as framework code.  ``ClairvoyantServer``
fronts N replica engines; each replica is a serial backend with its own
SJFQueue (+ starvation guard).  The multi-replica case routes by predicted
work (core/router.py, beyond paper).  Policies: "fcfs" | "sjf" |
"sjf_oracle" — the benchmark ablation is one constructor argument.

The virtual-clock drain loop is event-driven: at every dispatch decision the
queue applies the starvation check, exactly like the Go dispatcher goroutine.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.predictor import Predictor
from repro.core.router import PredictiveRouter
from repro.core.scheduler import Request, SJFQueue
from repro.serving.engine import SimEngine
from repro.serving.openai_api import CompletionRequest, CompletionResponse
from repro.serving.service_time import ServiceTimeModel, sample_output_tokens
from repro.data.tokenizer import approx_token_len


class ClairvoyantServer:
    def __init__(self, *, policy: str = "sjf", tau: Optional[float] = None,
                 n_replicas: int = 1,
                 predictor: Optional[Predictor] = None,
                 service_model: Optional[ServiceTimeModel] = None,
                 seed: int = 0):
        self.policy = policy
        self.predictor = predictor
        self.rng = np.random.default_rng(seed)
        self.service_model = service_model or ServiceTimeModel(
            prefill_tok_per_s=8000.0, decode_tok_per_s=60.0)
        self.engines = [SimEngine(self.service_model, i)
                        for i in range(n_replicas)]
        self.router = PredictiveRouter(n_replicas, policy=policy, tau=tau)
        self._inflight: Dict[int, CompletionRequest] = {}
        self._oracle_tokens: Dict[int, int] = {}
        self.responses: List[CompletionResponse] = []

    # ------------------------------------------------------------------ API
    def submit(self, req: CompletionRequest, *, arrival: float = 0.0,
               true_output_tokens: Optional[int] = None,
               klass: str = "") -> int:
        """Admit one request.  ``true_output_tokens`` is the oracle ground
        truth (known to the simulator, NOT the scheduler unless policy is
        sjf_oracle)."""
        if true_output_tokens is None:
            true_output_tokens = sample_output_tokens(
                self.rng, klass or "short")
        prompt_toks = approx_token_len(req.prompt)
        p_long = 0.0
        proba = None
        if self.predictor is not None and self.policy == "sjf":
            proba = self.predictor.proba_batch([req.prompt])[0]
            p_long = float(proba[2])
        r = Request(req_id=req.request_id, prompt=req.prompt, arrival=arrival,
                    p_long=p_long, klass=klass,
                    true_service=self.service_model.service(
                        prompt_toks, true_output_tokens),
                    tenant=req.tenant,
                    meta={"prompt_tokens": prompt_toks,
                          "output_tokens": true_output_tokens})
        self._inflight[req.request_id] = req
        self._oracle_tokens[req.request_id] = true_output_tokens
        return self.router.route(r, proba=proba, now=arrival)

    def cancel(self, request_id: int) -> bool:
        """Client disconnect: lazy-delete from whichever queue holds it."""
        for rep in self.router.replicas:
            if rep.queue.cancel(request_id):
                self._inflight.pop(request_id, None)
                return True
        return False

    def drain(self) -> List[CompletionResponse]:
        """Run every replica's serial loop to completion (virtual time)."""
        for rep, eng in zip(self.router.replicas, self.engines):
            t = eng.busy_until
            while True:
                req = rep.queue.pop(now=t)
                if req is None:
                    break
                t = max(t, req.arrival)
                ttft, service = eng.execute(
                    t, req.meta["prompt_tokens"], req.meta["output_tokens"])
                req.start, req.finish = t, t + service
                t += service
                self.router.on_dispatch(rep.replica_id, req, t,
                                        service_estimate=service)
                self.responses.append(CompletionResponse(
                    request_id=req.req_id, text="",
                    tokens_generated=req.meta["output_tokens"],
                    queue_wait_s=req.start - req.arrival,
                    service_s=service, ttft_s=req.start - req.arrival + ttft,
                    promoted=req.promoted, replica=rep.replica_id,
                    p_long=req.p_long))
        return self.responses

    # ---------------------------------------------------------------- stats
    def percentile(self, q: float, klass: Optional[str] = None,
                   attr: str = "sojourn_s") -> float:
        vals = [getattr(r, attr) for r in self.responses
                if klass is None or self._klass_of(r) == klass]
        return float(np.percentile(vals, q)) if vals else float("nan")

    def _klass_of(self, resp: CompletionResponse) -> str:
        toks = resp.tokens_generated
        return "short" if toks < 200 else ("medium" if toks < 800 else "long")

    @property
    def promotions(self) -> int:
        return sum(rep.queue.stats["promotions"]
                   for rep in self.router.replicas)
