"""The Clairvoyant sidecar: features -> predictor -> SJF queue -> engine.

This is the paper's Figure 2 as framework code.  ``ClairvoyantServer``
fronts N replica engines; each replica is a serial backend with its own
SJFQueue (+ starvation guard).  The multi-replica case routes by predicted
work (core/router.py, beyond paper).  The scheduling policy is a
first-class ``core.policy.Policy`` (registry name or instance): the seed
"fcfs" / "sjf" / "sjf_oracle" plus preemptive SRPT, quantile-aware SJF,
MLFQ and per-tenant fair share — the benchmark ablation is one
constructor argument.  Preemptive policies evict the running request at
the next fused-decode segment boundary (real engines: cancel + resume by
re-prefilling prompt + generated prefix; sim engines: the preemptive DES
in virtual time).

Two backends share the queueing layer:

* the default ``SimEngine`` fleet serves in virtual time from a
  ``ServiceTimeModel`` (thousands of requests, the queueing benchmarks);
* passing ``engines=[RealEngine(...), ...]`` serves each dispatched request
  with an actual fused on-device decode (serving/engine.py) and measured
  wall-clock service times — the end-to-end path the serve benchmark
  exercises (predictor -> SJF queue -> real decode);
* passing ``engines=[BatchedRealEngine(...)]`` drains the queue through
  bounded-concurrency decode lanes under a KV-memory budget
  (``_drain_batched``): back-fill pops via ``SJFQueue.pop_many`` so
  aging promotions are observed between pops, admission blocks on the
  budget in strict policy order, and client disconnects evict their
  lane at the next segment boundary.  Preemptive policies use the
  serial drain (lane eviction by key is future work).

Admission is batched: ``submit_many`` runs feature extraction + GBDT
prediction once across an arrival burst (the PR 1 ``proba_batch`` fast
path); ``submit`` is the single-request convenience wrapper over the same
``_admit``.

The virtual-clock drain loop is event-driven: at every dispatch decision the
queue applies the starvation check, exactly like the Go dispatcher goroutine.
Mid-generation disconnects on a real backend go through ``cancel``: if the
request is currently decoding, the engine's cancel flag stops the fused loop
at the next segment boundary (§3.4 drain semantics).

Robustness (PR 6) — the drain loops are exception-safe and every
submitted request terminates with exactly one terminal
``CompletionResponse`` (``ok | shed | failed | timeout | cancelled``),
the **no-lost-requests invariant** (enforced: a second terminal response
for the same request raises).  The pieces:

* ``fault_plan`` — a seeded ``serving.faults.FaultPlan`` injects engine
  crashes (virtual-time for sim drains, fused-decode segment boundaries
  for real engines), straggler stall windows, transient backend errors,
  predictor outages and admission-overflow windows.
* engine faults (injected or organic ``Exception`` from an engine call)
  requeue the in-flight request with its original arrival (sojourn
  accounting is preserved) under a jittered-exponential ``RetryPolicy``;
  retries exhausted => terminal ``failed`` response, never a raise.
* ``deadline_s`` — per-request queue-wait budget: a request still
  undispatched past its budget is shed at dispatch time (terminal
  ``shed`` response), bounding tail latency under overload.
* graceful predictor degradation — a predictor exception, NaN scores,
  or an injected outage flips the server into degraded mode
  (``self.degraded``): admission continues with ``p_long = 0`` for
  every request, which collapses SJF to FCFS (equal keys -> FIFO
  tie-break), and recovers as soon as a later predictor call succeeds.
* per-replica circuit breaker (``breaker=``) — consecutive recorded
  failures stop placement on a replica until a half-open probe succeeds
  (core/router.py).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.policy import get_policy
from repro.core.predictor import Predictor
from repro.core.router import PredictiveRouter
from repro.core.scheduler import Request, SJFQueue
from repro.serving.engine import BatchedRealEngine, RealEngine, SimEngine
from repro.serving.faults import (CircuitBreaker, EngineCrash, FaultError,
                                  RetryPolicy, TransientBackendError,
                                  as_injector)
from repro.serving.observability import Observability, record_service_spans
from repro.serving.openai_api import CompletionRequest, CompletionResponse
from repro.serving.service_time import ServiceTimeModel, sample_output_tokens
from repro.data.tokenizer import HashTokenizer, approx_token_len


class ClairvoyantServer:
    def __init__(self, *, policy="sjf", tau: Optional[float] = None,
                 n_replicas: int = 1,
                 predictor: Optional[Predictor] = None,
                 service_model: Optional[ServiceTimeModel] = None,
                 engines: Optional[Sequence] = None,
                 seed: int = 0,
                 fault_plan=None,
                 retry: Optional[RetryPolicy] = None,
                 deadline_s: Optional[float] = None,
                 deadline_mode: str = "queue",
                 max_queue_depth: Optional[int] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 observability: Optional[Observability] = None):
        # policy: registry name or Policy instance (core/policy.py)
        self.policy_obj = get_policy(policy)
        self.policy = self.policy_obj.name
        self.predictor = predictor
        self.rng = np.random.default_rng(seed)
        self.service_model = service_model or ServiceTimeModel(
            prefill_tok_per_s=8000.0, decode_tok_per_s=60.0)
        if engines is not None:
            self.engines = list(engines)
            n_replicas = len(self.engines)
        else:
            self.engines = [SimEngine(self.service_model, i)
                            for i in range(n_replicas)]
        self.router = PredictiveRouter(n_replicas, policy=policy, tau=tau,
                                       breaker=breaker)
        self._inflight: Dict[int, CompletionRequest] = {}
        self._decoding: Dict[int, int] = {}     # replica_id -> request_id
        self._disconnected: set = set()         # mid-flight client cancels
        self._oracle_tokens: Dict[int, int] = {}
        self._tokenizer: Optional[HashTokenizer] = None
        self.responses: List[CompletionResponse] = []
        # --- robustness layer (serving/faults.py) ---
        self.faults = as_injector(fault_plan)
        self.retry = retry if retry is not None else RetryPolicy(seed=seed)
        self.deadline_s = deadline_s
        # "queue" (PR 6): deadline bounds QUEUE WAIT only — undispatched
        # work is shed, started work always completes.  "sojourn": the
        # deadline bounds arrival-to-finish — pre-dispatch expiry still
        # sheds, but expiry MID-SERVICE terminates with status "timeout"
        # (the wire semantics the async sidecar exposes).
        if deadline_mode not in ("queue", "sojourn"):
            raise ValueError(f"unknown deadline_mode {deadline_mode!r}")
        self.deadline_mode = deadline_mode
        self.max_queue_depth = max_queue_depth
        self.degraded = False                   # predictor-outage FCFS mode
        self._terminal: Dict[int, str] = {}     # req_id -> terminal status
        self._next_id = 1                       # per-server request-id space
        self.fault_stats = {"predictor_failures": 0,
                            "degraded_admissions": 0, "sheds": 0,
                            "retries": 0, "failures": 0, "crashes": 0,
                            "transients": 0, "requeues": 0, "timeouts": 0}
        if self.faults is not None:
            for eng in self.engines:
                if isinstance(eng, RealEngine):
                    eng.fault_injector = self.faults
        # --- observability (serving/observability.py) ---
        # self.obs is read per call site (``obs = self.obs``) so a sidecar
        # may attach one after construction; every hook is gated on the
        # component being present (zero cost when disabled).
        self.obs: Optional[Observability] = None
        self._obs_arrival: Dict[int, float] = {}   # req_id -> arrival time
        if observability is not None:
            self.attach_observability(observability)

    def attach_observability(self, obs: Observability) -> None:
        """Wire the flight recorder + metrics registry into the stack:
        the router's route-decision instants, the batched engines' lane
        spans, and the scrape-time collectors over stats the server and
        engines already keep."""
        self.obs = obs
        self.router.recorder = obs.recorder
        for eng in self.engines:
            if hasattr(eng, "recorder"):
                eng.recorder = obs.recorder
        obs.register_server(self)
        obs.register_engines(self.engines)

    # ------------------------------------------------------------------ API
    def _predict_probas(self, prompts: List[str], now: float,
                        rid_hint: Optional[int] = None):
        """Predictor call with graceful degradation: an exception, a
        non-finite score, or an injected outage window returns None (the
        caller admits with ``p_long = 0`` for all — FCFS order) and flips
        ``self.degraded``; a later successful call heals the server back
        to predictive SJF.  Never raises to the submitting client.

        When a flight recorder is attached, the two admission stages are
        timed separately (feature_extract / predict spans, placed at the
        batch's arrival instant with measured wall durations) and the
        per-request predictor latency feeds its histogram — the paper's
        0.029 ms claim, observable on live traffic."""
        if self.predictor is None or not self.policy_obj.uses_predictor \
                or not prompts:
            return None
        obs = self.obs
        rec = obs.recorder if obs is not None else None
        probas = None
        if self.faults is None or not self.faults.predictor_down(now):
            try:
                if obs is not None and isinstance(self.predictor, Predictor):
                    import time as _time
                    from repro.core import features as _F
                    rid = rid_hint if rid_hint is not None else self._next_id
                    w0 = _time.perf_counter()
                    X = _F.extract_batch(prompts)
                    w1 = _time.perf_counter()
                    probas = np.asarray(
                        self.predictor.model.predict_proba(X), float)
                    w2 = _time.perf_counter()
                    if rec is not None:
                        trk = f"req{rid}"
                        rec.span("feature_extract", rid, now,
                                 now + (w1 - w0), track=trk,
                                 args={"batch": len(prompts)})
                        rec.span("predict", rid, now + (w1 - w0),
                                 now + (w2 - w0), track=trk,
                                 args={"batch": len(prompts)})
                    obs.observe_predict(len(prompts), w2 - w0)
                else:
                    probas = np.asarray(
                        self.predictor.proba_batch(prompts), float)
                if not np.all(np.isfinite(probas)):
                    probas = None                # NaN/inf scores: degrade
            except Exception:
                probas = None                    # predictor raised: degrade
        if probas is None:
            self.fault_stats["predictor_failures"] += 1
            self.degraded = True
            return None
        self.degraded = False                    # predictor healed
        return probas

    def allocate_id(self) -> int:
        """Reserve the next request id from this server's id space (the
        sidecar pre-assigns ids so it can register a waiter before the
        admission path can emit a terminal shed response)."""
        rid = self._next_id
        self._next_id += 1
        return rid

    def submit(self, req: CompletionRequest, *, arrival: float = 0.0,
               true_output_tokens: Optional[int] = None,
               klass: str = "", deadline_s: Optional[float] = None) -> int:
        """Admit one request.  ``true_output_tokens`` is the oracle ground
        truth (known to the simulator, NOT the scheduler unless policy is
        sjf_oracle).  ``deadline_s`` overrides the server-wide budget for
        this request.  Returns the chosen replica, or -1 if the request
        was shed at admission (queue overflow)."""
        probas = self._predict_probas([req.prompt], arrival,
                                      rid_hint=req.request_id)
        return self._admit(req, None if probas is None else probas[0],
                           arrival, true_output_tokens, klass,
                           deadline_s=deadline_s)

    def submit_many(self, reqs: Sequence[CompletionRequest], *,
                    arrivals: Optional[Sequence[float]] = None,
                    true_output_tokens: Optional[Sequence[int]] = None,
                    klasses: Optional[Sequence[str]] = None) -> List[int]:
        """Admit an arrival burst with ONE batched predictor call.

        Feature extraction + GBDT scoring run once over the whole batch
        (``Predictor.proba_batch``, the PR 1 vectorized admission fast
        path) instead of once per request — ~10x cheaper per request at
        realistic burst sizes.  Returns the chosen replica per request
        (-1 for requests shed at admission).
        """
        n = len(reqs)
        probas = self._predict_probas(
            [r.prompt for r in reqs],
            0.0 if arrivals is None or not n else float(arrivals[0]),
            rid_hint=reqs[0].request_id if n else None)
        return [
            self._admit(
                req,
                None if probas is None else probas[i],
                0.0 if arrivals is None else float(arrivals[i]),
                None if true_output_tokens is None else int(true_output_tokens[i]),
                "" if klasses is None else klasses[i])
            for i, req in enumerate(reqs)
        ]

    def _admit(self, req: CompletionRequest, proba, arrival: float,
               true_output_tokens: Optional[int], klass: str,
               deadline_s: Optional[float] = None) -> int:
        # per-server id space: assign at admission (dense, deterministic
        # per server); explicit ids are honored but may not collide with
        # a request this server has already seen
        if req.request_id is None:
            req.request_id = self.allocate_id()
        else:
            self._next_id = max(self._next_id, int(req.request_id) + 1)
        if req.request_id in self._terminal \
                or req.request_id in self._inflight:
            raise ValueError(f"request id {req.request_id} already "
                             "submitted to this server")
        obs = self.obs
        if obs is not None:
            # arrival anchors the root "request" span emitted at _finish
            self._obs_arrival[req.request_id] = arrival
            obs.observe_admission(1, self.policy)
        if true_output_tokens is None:
            true_output_tokens = sample_output_tokens(
                self.rng, klass or "short")
        prompt_toks = approx_token_len(req.prompt)
        p_long = float(proba[2]) if proba is not None else 0.0
        degraded = proba is None and self.degraded \
            and self.policy_obj.uses_predictor
        r = Request(req_id=req.request_id, prompt=req.prompt, arrival=arrival,
                    p_long=p_long, klass=klass,
                    true_service=self.service_model.service(
                        prompt_toks, true_output_tokens),
                    tenant=req.tenant,
                    meta={"prompt_tokens": prompt_toks,
                          "output_tokens": true_output_tokens})
        if deadline_s is not None:
            r.meta["deadline_s"] = float(deadline_s)
        if degraded:
            r.meta["degraded"] = True
            self.fault_stats["degraded_admissions"] += 1
        # bounded admission queue / injected overflow window: shed, never
        # enqueue-and-forget
        depth = sum(len(rep.queue) for rep in self.router.replicas)
        if (self.max_queue_depth is not None
                and depth >= self.max_queue_depth) \
                or (self.faults is not None
                    and self.faults.overflow_active(arrival)):
            self.fault_stats["sheds"] += 1
            self._finish(CompletionResponse(
                request_id=req.request_id, text="", tokens_generated=0,
                queue_wait_s=0.0, service_s=0.0, replica=-1,
                p_long=p_long, klass=klass, status="shed",
                error="admission queue overflow", degraded=degraded))
            return -1
        self._inflight[req.request_id] = req
        self._oracle_tokens[req.request_id] = true_output_tokens
        return self.router.route(r, proba=proba, now=arrival)

    # -------------------------------------------------------- terminal path
    def _finish(self, resp: CompletionResponse) -> None:
        """The single exit gate: every submitted request passes through
        here exactly once (the no-lost-requests invariant — a duplicate
        terminal response is a scheduler bug and raises)."""
        prev = self._terminal.get(resp.request_id)
        if prev is not None:
            raise RuntimeError(
                f"request {resp.request_id} already terminated "
                f"({prev!r}); duplicate terminal status {resp.status!r}")
        self._terminal[resp.request_id] = resp.status
        self._inflight.pop(resp.request_id, None)
        self.responses.append(resp)
        obs = self.obs
        if obs is not None:
            obs.observe_terminal(
                resp, self._obs_arrival.pop(resp.request_id, None))

    def _deadline_of(self, req) -> Optional[float]:
        """Effective deadline budget for one request: the per-request
        override (``submit(..., deadline_s=)``) or the server-wide one."""
        return req.meta.get("deadline_s", self.deadline_s)

    def _maybe_shed(self, rep, req, now: float) -> bool:
        """Deadline-budget load shedding at dispatch time: a request that
        has not started and is already past its queue-wait budget is shed
        with a terminal response (bounds the tail under overload)."""
        dl = self._deadline_of(req)
        if dl is None or req.start is not None \
                or (now - req.arrival) <= dl:
            return False
        self.router.release(rep.replica_id, req)
        self.fault_stats["sheds"] += 1
        req.finish = now
        obs = self.obs
        if obs is not None and obs.recorder is not None:
            obs.recorder.span("queue_wait", req.req_id, req.arrival, now,
                              track=f"req{req.req_id}")
        self._finish(CompletionResponse(
            request_id=req.req_id, text="", tokens_generated=0,
            queue_wait_s=max(0.0, now - req.arrival), service_s=0.0,
            replica=rep.replica_id, p_long=req.p_long, klass=req.klass,
            status="shed", error="deadline budget exceeded before dispatch",
            retries=req.meta.get("fault_retries", 0),
            degraded=bool(req.meta.get("degraded"))))
        return True

    def _retry_or_fail(self, rep, req, now: float, exc: Exception,
                       charge_backoff: bool = True) -> float:
        """Shared fault epilogue for all drain loops: the popped request
        either re-enters its queue (bounded retries, original arrival
        preserved) or terminates with a ``failed`` response.  Returns the
        (possibly backoff-advanced) clock."""
        n = req.meta.get("fault_retries", 0) + 1
        req.meta["fault_retries"] = n
        self.router.record_failure(rep.replica_id, now)
        if isinstance(exc, EngineCrash):
            self.fault_stats["crashes"] += 1
        elif isinstance(exc, TransientBackendError):
            self.fault_stats["transients"] += 1
        if n > self.retry.max_retries:
            self.fault_stats["failures"] += 1
            self.router.release(rep.replica_id, req)
            start = req.start if req.start is not None else now
            req.finish = now
            self._finish(CompletionResponse(
                request_id=req.req_id, text="", tokens_generated=0,
                queue_wait_s=max(0.0, start - req.arrival),
                service_s=max(0.0, now - start),
                replica=rep.replica_id, p_long=req.p_long, klass=req.klass,
                status="failed", error=f"{type(exc).__name__}: {exc}",
                retries=n, degraded=bool(req.meta.get("degraded"))))
            return now
        self.fault_stats["retries"] += 1
        self.fault_stats["requeues"] += 1
        if charge_backoff:
            now += self.retry.backoff(n - 1)
        rep.queue.push_requeue(
            req, req.meta.get("queue_key",
                              req.meta.get("policy_key0", 0.0)),
            reason="fault")
        return now

    def cancel(self, request_id: int) -> bool:
        """Client disconnect: lazy-delete from whichever queue holds it; if
        it is mid-generation on a real engine, flag the fused loop to drain
        at the next segment boundary.  A queued cancel terminates the
        request immediately with a ``cancelled`` response; a mid-flight
        cancel terminates when the drain loop observes the eviction —
        either way the request is never silently dropped."""
        for rep in self.router.replicas:
            req = rep.queue._live.get(request_id)
            if rep.queue.cancel(request_id):
                self.router.release(rep.replica_id, req)
                self._finish(CompletionResponse(
                    request_id=request_id, text="", tokens_generated=0,
                    queue_wait_s=0.0, service_s=0.0,
                    replica=rep.replica_id,
                    p_long=req.p_long, klass=req.klass,
                    status="cancelled", error="client disconnect (queued)",
                    degraded=bool(req.meta.get("degraded"))))
                return True
        for eng in self.engines:
            # mid-flight on a batched engine: flag the lane; the drain
            # loop evicts it at the next segment boundary
            if isinstance(eng, BatchedRealEngine) \
                    and eng.lane_manager is not None \
                    and eng.lane_manager.lane_of(request_id) is not None:
                self._disconnected.add(request_id)
                return True
        for replica_id, rid in self._decoding.items():
            if rid == request_id:
                eng = self.engines[replica_id]
                if hasattr(eng, "request_cancel"):
                    # distinguishes a disconnect from a preemption eviction:
                    # the drain loop drops disconnected requests instead of
                    # re-enqueueing them
                    self._disconnected.add(request_id)
                    eng.request_cancel()
                    return True
        return False

    def drain(self, max_new_tokens: int = 64) -> List[CompletionResponse]:
        """Run every replica's serial loop to completion.

        SimEngine replicas advance a virtual clock from the service-time
        model; RealEngine replicas actually decode each request (fused loop)
        and feed the measured wall-clock service time into the same clock.
        """
        for rep, eng in zip(self.router.replicas, self.engines):
            if isinstance(eng, BatchedRealEngine) \
                    and not self.policy_obj.preemptive:
                self._drain_batched(rep, eng, max_new_tokens)
            elif isinstance(eng, RealEngine):
                self._drain_real(rep, eng, max_new_tokens)
            else:
                self._drain_sim(rep, eng)
        return self.responses

    def _drain_sim(self, rep, eng) -> None:
        """Virtual-clock serial drain, exception-safe: every popped
        request terminates through ``_finish`` (ok / shed / failed) or
        re-enters the queue — injected faults (transient errors, stalls,
        crash + repair) and organic engine exceptions both route through
        ``_retry_or_fail``.  The loop always re-pops, so a requeued
        request is served later in this same drain."""
        if self.policy_obj.preemptive:
            self._drain_sim_preemptive(rep, eng)
            return
        inj = self.faults
        rid = rep.replica_id
        obs = self.obs
        rec = obs.recorder if obs is not None else None
        trk = f"replica{rid}"
        t = eng.busy_until
        while True:
            req = rep.queue.pop(now=t)
            if req is None:
                break
            t = max(t, req.arrival)
            if self._maybe_shed(rep, req, t):
                continue
            # injected transient backend error: fails this attempt before
            # any service is rendered
            if inj is not None:
                spec = inj.transient_due(rid, t)
                if spec is not None:
                    t = self._retry_or_fail(rep, req, t,
                                            TransientBackendError(
                                                "injected transient "
                                                "backend error"))
                    continue
            if req.start is None:
                req.start = t                  # first dispatch
            try:
                ttft, service = self._sim_execute(eng, rid, t, req)
            except FaultError as e:
                # engine crash mid-service: the clock is already advanced
                # to the end of the repair window by _sim_execute
                t = self._retry_or_fail(rep, req, eng.busy_until, e,
                                        charge_backoff=False)
                continue
            except Exception as e:             # organic engine bug
                t = self._retry_or_fail(rep, req, t, e)
                continue
            if self.deadline_mode == "sojourn":
                dl = self._deadline_of(req)
                if dl is not None and t + service > req.arrival + dl:
                    # in-service expiry: the attempt is abandoned AT the
                    # deadline instant with a terminal ``timeout`` (the
                    # pre-dispatch case stays ``shed`` via _maybe_shed)
                    expiry = max(t, req.arrival + dl)
                    eng.busy_until = expiry
                    self.router.release(rid, req)
                    self.fault_stats["timeouts"] += 1
                    req.finish = expiry
                    if rec is not None:
                        record_service_spans(
                            rec, req.req_id, arrival=req.arrival,
                            start=t, finish=expiry,
                            ttft=min(ttft, expiry - t),
                            out_tokens=req.meta["output_tokens"],
                            track=trk)
                    self._finish(CompletionResponse(
                        request_id=req.req_id, text="", tokens_generated=0,
                        queue_wait_s=req.start - req.arrival,
                        service_s=max(0.0, expiry - req.start),
                        ttft_s=(req.start - req.arrival + ttft)
                        if t + ttft <= expiry else None,
                        promoted=req.promoted, replica=rid,
                        p_long=req.p_long, klass=req.klass,
                        status="timeout",
                        error="deadline expired in service",
                        retries=req.meta.get("fault_retries", 0),
                        degraded=bool(req.meta.get("degraded"))))
                    t = expiry
                    continue
            t += service
            req.finish = t
            self.router.on_dispatch(rid, req, t, service_estimate=service)
            self.router.record_success(rid, t)
            retries = req.meta.get("fault_retries", 0)
            if rec is not None:
                record_service_spans(
                    rec, req.req_id, arrival=req.arrival,
                    start=t - service, finish=t, ttft=ttft,
                    out_tokens=req.meta["output_tokens"], track=trk)
            self._finish(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=req.meta["output_tokens"],
                queue_wait_s=req.start - req.arrival,
                # a fault-requeued request reports time-in-service across
                # the gaps so sojourn_s == finish - arrival stays exact
                service_s=service if retries == 0 else t - req.start,
                ttft_s=req.start - req.arrival + ttft,
                promoted=req.promoted, replica=rid,
                p_long=req.p_long, klass=req.klass, retries=retries,
                degraded=bool(req.meta.get("degraded"))))

    def _sim_execute(self, eng, rid: int, t: float, req) -> tuple:
        """One virtual-time service attempt with fault injection.  Returns
        ``(ttft, service)`` and advances the engine clock on success; on
        an injected crash raises :class:`EngineCrash` with the engine
        parked at the end of its repair window and the request's partial
        progress recorded (work-conserving requeue: the next attempt only
        serves the remaining work)."""
        ptoks = req.meta["prompt_tokens"]
        otoks = req.meta["output_tokens"]
        full = eng.model.service(ptoks, otoks)
        used = req.meta.get("sim_used_s", 0.0)
        rem = max(full - used, 0.0)
        inj = self.faults
        if inj is not None:
            rem *= inj.stall_factor(rid, t)    # straggler window
            crash = inj.crash_between(rid, t, t + rem)
            if crash is not None:
                crash_t = max(t, crash.at)
                req.meta["sim_used_s"] = used + (crash_t - t)
                eng.busy_until = crash_t + crash.repair_s
                raise EngineCrash("injected engine crash mid-service",
                                  at=crash_t, repair_s=crash.repair_s)
        ttft = eng.model.overhead_s + ptoks / eng.model.prefill_tok_per_s
        eng.busy_until = t + rem
        eng.served += 1
        return ttft, rem

    def _drain_sim_preemptive(self, rep, eng) -> None:
        """Virtual-time drain under a preemptive policy: the replica's
        whole backlog runs through the preemptive DES engine (arrival
        events slice service; evicted work is re-enqueued with the
        policy's requeue key), then responses are emitted in finish
        order.  ``queue_wait_s`` is time to FIRST dispatch."""
        from repro.core.sim_fast import RequestBatch, simulate_batch
        reqs = rep.queue.waiting()
        for r in reqs:                       # drain the queue bookkeeping
            rep.queue.remove(r.req_id)
            rep.queue.stats["dispatched"] += 1
        if not reqs:
            return
        batch = RequestBatch.from_requests(reqs)
        # the engine may still be busy from a previous drain: nothing can
        # start before busy_until, so clamp the simulated arrivals (waits
        # are still reported against the TRUE arrival, like _drain_sim)
        batch.arrival = np.maximum(batch.arrival, eng.busy_until)
        res = simulate_batch(batch, policy=self.policy_obj,
                             tau=rep.queue.tau)
        rep.queue.stats["promotions"] += res.promotions
        rep.queue.stats["preemptions"] += res.preemptions
        obs = self.obs
        rec = obs.recorder if obs is not None else None
        order = np.argsort(res.finish, kind="stable")
        for i in order:
            req = reqs[i]
            req.start = float(res.start[i])
            req.finish = float(res.finish[i])
            req.promoted = bool(res.promoted[i])
            service = req.true_service
            ttft = (eng.model.overhead_s + req.meta["prompt_tokens"]
                    / eng.model.prefill_tok_per_s)
            if rec is not None:
                # preempted services interleave, so [start, finish]
                # windows of different requests can partially overlap:
                # each request gets its own sub-track of the replica
                record_service_spans(
                    rec, req.req_id, arrival=req.arrival, start=req.start,
                    finish=req.finish, ttft=ttft,
                    out_tokens=req.meta["output_tokens"],
                    track=f"replica{rep.replica_id}/req{req.req_id}")
            eng.busy_until = max(eng.busy_until, req.finish)
            eng.served += 1
            self.router.on_dispatch(rep.replica_id, req, req.finish,
                                    service_estimate=service)
            self._finish(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=req.meta["output_tokens"],
                queue_wait_s=req.start - req.arrival,
                # time in service INCLUDING eviction gaps, so sojourn_s
                # (wait + service) equals finish - arrival exactly
                service_s=req.finish - req.start,
                ttft_s=req.start - req.arrival + ttft,
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass,
                degraded=bool(req.meta.get("degraded"))))

    def _drain_real(self, rep, eng: RealEngine, max_new_tokens: int) -> None:
        """Serial wall-clock loop: pop -> tokenize -> fused decode.

        Under a preemptive policy, a queued request whose key strictly
        beats the running one (or, for MLFQ, a running request that
        exhausts its quantum) stops the fused loop at the next segment
        boundary (§3.4 cancellation); the evicted request re-enters the
        queue with its policy requeue key and the tokens generated so
        far, and later resumes by re-prefilling prompt + generated prefix
        (cheap re-prefill: greedy decode makes the resumed sequence
        bitwise-identical to an uninterrupted one).
        """
        import time as _time
        from repro.core.policy import MODE_SRPT
        if self._tokenizer is None:
            self._tokenizer = HashTokenizer(eng.cfg.vocab_size)
        pol = self.policy_obj
        obs = self.obs
        rec = obs.recorder if obs is not None else None
        trk = f"replica{rep.replica_id}"
        t = eng.busy_until
        while True:
            if pol.preemptive:
                req, t = self._pop_arrival_aware(rep, t)
            else:
                req = rep.queue.pop(now=t)
            if req is None:
                break
            t = max(t, req.arrival)
            if self._maybe_shed(rep, req, t):
                continue
            ids, n_total, resume = self._prepare_ids(req, eng,
                                                     max_new_tokens)
            n_new = max(1, n_total - len(resume))
            used = req.meta.get("used_s", 0.0)
            key0 = req.meta.get("policy_key0", 0.0)
            level = req.meta.get("mlfq_level", 0)
            evict_reason = []
            cancel_cb = None
            if pol.preemptive:
                wall0 = _time.monotonic()
                # SRPT decays from the ADMISSION key by total service
                # received; level policies carry their current queue key.
                # used/elapsed are wall seconds against model-calibrated
                # keys — an approximation unless the policy's short/long
                # moments are calibrated to this engine.
                base_key = key0 if pol.mode == MODE_SRPT \
                    else req.meta.get("queue_key", key0)

                def cancel_cb():
                    elapsed = _time.monotonic() - wall0
                    best = self._best_eligible(rep, t + elapsed)
                    if best is None:
                        return False
                    quantum = pol.quantum(req.p_long)
                    if (quantum is not None and level == 0
                            and used + elapsed > quantum):
                        evict_reason.append("quantum")
                        return True
                    run_key = pol.running_key(base_key, used + elapsed)
                    if pol.should_preempt(run_key, best[0]):
                        evict_reason.append("preempt")
                        return True
                    return False

            deadline_hit = []
            dl = self._deadline_of(req) \
                if self.deadline_mode == "sojourn" else None
            if dl is not None:
                wall_dl0 = _time.monotonic()
                waited = max(0.0, t - req.arrival)
                inner_cb = cancel_cb

                def cancel_cb(_inner=inner_cb, _w0=wall_dl0, _dl=dl,
                              _waited=waited):
                    # sojourn budget: queue wait already spent + wall time
                    # in this attempt; expiry stops the fused loop at the
                    # next segment boundary -> terminal ``timeout``
                    if _waited + (_time.monotonic() - _w0) > _dl:
                        deadline_hit.append(True)
                        return True
                    return _inner() if _inner is not None else False

            if req.start is None:
                req.start = t                 # first dispatch
            # injected transient backend error at dispatch time
            if self.faults is not None:
                spec = self.faults.transient_due(rep.replica_id, t)
                if spec is not None:
                    t = self._retry_or_fail(rep, req, t,
                                            TransientBackendError(
                                                "injected transient "
                                                "backend error"))
                    continue
            self._decoding[rep.replica_id] = req.req_id
            wall_gen0 = _time.monotonic()
            seg_marks: List[float] = []
            on_seg = None
            if rec is not None:
                # real fused-decode segment boundaries, stamped in wall
                # time and mapped onto the drain clock below
                def on_seg(new_toks, _m=seg_marks):
                    _m.append(_time.monotonic())
            try:
                out = eng.generate(ids, max_new_tokens=n_new,
                                   cancel_cb=cancel_cb, on_segment=on_seg)
            except Exception as e:
                # engine crash mid-generation (injected at a segment
                # boundary, or organic): the popped request must not be
                # lost — charge the wall time burned, then requeue or
                # fail through the shared epilogue.  Tokens decoded by
                # the dead engine are gone (no resume credit).
                elapsed = _time.monotonic() - wall_gen0
                t += elapsed
                if isinstance(e, EngineCrash):
                    t += e.repair_s           # replica down for repair
                eng.busy_until = t
                t = self._retry_or_fail(rep, req, t, e)
                continue
            finally:
                self._decoding.pop(rep.replica_id, None)
            service = out["service_s"]
            tokens = list(resume) + list(out["tokens"])
            req.meta.setdefault("ttft_s", out["ttft_s"])
            t += service
            eng.busy_until = t
            emit_spans = None
            if rec is not None:
                _t0, _t1, _ttft = t - service, t, out["ttft_s"]

                def emit_spans(_a=req.arrival, _rid=req.req_id, _t0=_t0,
                               _t1=_t1, _ttft=_ttft, _w0=wall_gen0,
                               _marks=seg_marks):
                    # queue_wait/prefill/decode from the attempt window;
                    # decode_segment edges from the measured boundaries
                    record_service_spans(rec, _rid, arrival=_a, start=_t0,
                                         finish=_t1, ttft=_ttft,
                                         max_segments=0, track=trk)
                    edges = [min(_t0 + _ttft, _t1)]
                    for m in _marks:
                        edges.append(min(max(_t0 + (m - _w0), edges[-1]),
                                         _t1))
                    edges.append(_t1)
                    for i in range(len(edges) - 1):
                        rec.span("decode_segment", _rid, edges[i],
                                 edges[i + 1], track=trk)
            if out.get("cancelled"):
                if req.req_id in self._disconnected:
                    self._disconnected.discard(req.req_id)
                    req.finish = t
                    if emit_spans is not None:
                        emit_spans()
                    self._finish(CompletionResponse(
                        request_id=req.req_id, text="",
                        tokens_generated=len(tokens),
                        queue_wait_s=req.start - req.arrival,
                        service_s=used + service,
                        ttft_s=req.start - req.arrival + req.meta["ttft_s"],
                        promoted=req.promoted, replica=rep.replica_id,
                        p_long=req.p_long, klass=req.klass,
                        status="cancelled",
                        error="client disconnect (mid-generation)",
                        degraded=bool(req.meta.get("degraded"))))
                    continue                  # client disconnect: drained
                if deadline_hit:
                    self.fault_stats["timeouts"] += 1
                    self.router.release(rep.replica_id, req)
                    req.finish = t
                    if emit_spans is not None:
                        emit_spans()
                    self._finish(CompletionResponse(
                        request_id=req.req_id, text="",
                        tokens_generated=len(tokens),
                        queue_wait_s=req.start - req.arrival,
                        service_s=used + service,
                        ttft_s=req.start - req.arrival + req.meta["ttft_s"],
                        promoted=req.promoted, replica=rep.replica_id,
                        p_long=req.p_long, klass=req.klass,
                        status="timeout",
                        error="deadline expired in service",
                        retries=req.meta.get("fault_retries", 0),
                        degraded=bool(req.meta.get("degraded"))))
                    continue                  # in-service deadline expiry
                if len(tokens) >= n_total:
                    pass                      # done at the boundary anyway
                else:
                    # preemption / demotion: re-enqueue the remaining work
                    self._requeue_evicted(rep, req, tokens, used + service,
                                          key0, level, evict_reason)
                    continue
            total_service = used + service
            req.finish = t
            self.router.on_dispatch(rep.replica_id, req, t,
                                    service_estimate=total_service)
            self.router.record_success(rep.replica_id, t)
            if emit_spans is not None:
                emit_spans()
            self._finish(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=len(tokens),
                queue_wait_s=req.start - req.arrival,
                service_s=total_service,
                ttft_s=req.start - req.arrival + req.meta["ttft_s"],
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass,
                retries=req.meta.get("fault_retries", 0),
                degraded=bool(req.meta.get("degraded")),
                accept_rate=out.get("accept_rate")))

    def _drain_batched(self, rep, eng: BatchedRealEngine,
                       max_new_tokens: int) -> None:
        """Micro-batched wall-clock drain: up to ``eng.n_lanes`` requests
        decode concurrently under the engine's KV budget.

        The queue stays the single source of dispatch order: the engine's
        lane back-fill pulls through :meth:`SJFQueue.pop_many`, so the
        starvation guard is re-evaluated between every pop (a promoted
        waiter takes the next vacant lane even when its key sorts last).
        Admission is memory-aware — a head whose worst-case KV footprint
        does not fit the budget blocks back-fill until lanes retire.
        Client disconnects evict the lane at the next segment boundary
        (per-lane §3.4 semantics).  Preemptive policies use the serial
        ``_drain_real`` path (lane eviction by key is future work); the
        server routes them there before calling this.
        """
        import time as _time
        if self._tokenizer is None:
            self._tokenizer = HashTokenizer(eng.cfg.vocab_size)
        obs = self.obs
        rec = obs.recorder if obs is not None else None
        eng.recorder = rec                     # lane spans (engine.py)
        t_base = eng.busy_until
        wall0 = _time.monotonic()

        def now() -> float:
            return t_base + (_time.monotonic() - wall0)

        def source(k: int):
            items = []
            while len(items) < k:
                got = rep.queue.pop_many(k - len(items), now=now())
                if not got:
                    break
                for req in got:
                    if self._maybe_shed(rep, req, now()):
                        continue              # shed: pull a replacement
                    if rec is not None:
                        rec.span("queue_wait", req.req_id, req.arrival,
                                 now(), track=f"req{req.req_id}")
                    ids, n_total, resume = self._prepare_ids(
                        req, eng, max_new_tokens)
                    items.append({"req_id": req.req_id, "ids": ids,
                                  "max_new": max(1, n_total - len(resume)),
                                  "tenant": req.tenant,
                                  "meta": {"req": req,
                                           "resume": list(resume)}})
            return items

        def cancel_check(state) -> bool:
            if state.req_id in self._disconnected:
                return True
            if self.deadline_mode == "sojourn":
                req = state.meta["req"]
                dl = self._deadline_of(req)
                if dl is not None and (now() - req.arrival) > dl:
                    state.meta["deadline_hit"] = True
                    return True
            return False

        def requeue_or_fail(req, now_t) -> None:
            """Crashed-lane victim: bounded retry with the original
            arrival (and a resume prefix — re-prefill is work-conserving)
            or a terminal ``failed`` response."""
            self._retry_or_fail(rep, req, now_t, EngineCrash(
                "injected lane crash"), charge_backoff=False)

        def on_finish(state, out):
            req = state.meta["req"]
            tokens = state.meta["resume"] + out["tokens"]
            if req.start is None:
                req.start = max(out["admit_t"], req.arrival)
            if out.get("crashed"):
                # lane died at a segment boundary: keep the decoded prefix
                # for the resume re-prefill, then retry or fail
                req.meta["resume_tokens"] = tokens
                requeue_or_fail(req, out["finish_t"])
                return
            if out["cancelled"]:
                # disconnect wins over a deadline that fired the same
                # segment (the client is gone either way)
                timed_out = state.meta.get("deadline_hit") \
                    and req.req_id not in self._disconnected
                if timed_out:
                    self.fault_stats["timeouts"] += 1
                    self.router.release(rep.replica_id, req)
                else:
                    self._disconnected.discard(req.req_id)
                req.finish = max(out["finish_t"], req.start)
                self._finish(CompletionResponse(
                    request_id=req.req_id, text="",
                    tokens_generated=len(tokens),
                    queue_wait_s=req.start - req.arrival,
                    service_s=req.finish - req.start,
                    ttft_s=out["ttft_s"], promoted=req.promoted,
                    replica=rep.replica_id, p_long=req.p_long,
                    klass=req.klass,
                    status="timeout" if timed_out else "cancelled",
                    error="deadline expired in service" if timed_out
                    else "client disconnect (mid-generation)",
                    retries=req.meta.get("fault_retries", 0),
                    degraded=bool(req.meta.get("degraded"))))
                return
            req.finish = max(out["finish_t"], req.start)
            req.meta.setdefault("ttft_s", out["ttft_s"])
            self.router.on_dispatch(rep.replica_id, req, req.finish,
                                    service_estimate=out["service_s"])
            self.router.record_success(rep.replica_id, req.finish)
            self._finish(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=len(tokens),
                queue_wait_s=req.start - req.arrival,
                service_s=req.finish - req.start,
                ttft_s=req.start - req.arrival + req.meta["ttft_s"],
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass,
                retries=req.meta.get("fault_retries", 0),
                degraded=bool(req.meta.get("degraded")),
                accept_rate=out.get("accept_rate")))

        # exception-safe lane driving: a whole-engine crash raised from a
        # segment boundary evicts every busy lane back into the queue
        # (bounded per-request retries), and crash/requeue churn re-enters
        # run_lanes until the queue truly drains.  The pass cap is a
        # safety net — fault plans are finite, so it is never hit unless
        # an engine raises unboundedly, in which case remaining requests
        # terminate as failed instead of looping forever.
        for _pass in range(64):
            try:
                eng.run_lanes(source, on_finish, cancel_check=cancel_check,
                              now_fn=now)
            except Exception as e:
                t_err = now()
                mgr = eng.lane_manager
                if mgr is not None:
                    for lane in list(mgr.busy_lanes()):
                        st = mgr.evict(lane)
                        victim = st.meta["req"]
                        victim.meta["resume_tokens"] = \
                            st.meta["resume"] + list(st.tokens)
                        if victim.start is None:
                            victim.start = max(st.admit_t, victim.arrival)
                        self._retry_or_fail(rep, victim, t_err, e,
                                            charge_backoff=False)
                # items popped from the queue but not yet admitted to a
                # lane would otherwise vanish with the engine's stack
                for item in eng.take_pending():
                    pend = item["meta"]["req"]
                    self._retry_or_fail(rep, pend, t_err, e,
                                        charge_backoff=False)
            if not rep.queue.live():
                break
        else:
            for req in list(rep.queue.live()):
                rep.queue.remove(req.req_id)
                req.finish = now()
                self._finish(CompletionResponse(
                    request_id=req.req_id, text="", tokens_generated=0,
                    queue_wait_s=max(0.0, now() - req.arrival),
                    service_s=0.0, replica=rep.replica_id,
                    p_long=req.p_long, klass=req.klass, status="failed",
                    error="engine unable to drain (retry passes exhausted)",
                    retries=req.meta.get("fault_retries", 0)))
        eng.busy_until = now()

    def _prepare_ids(self, req, eng, max_new_tokens: int):
        """Token budget + input ids for one dispatch, shared by the serial
        and batched drains (their truncation must match exactly — the
        batched engine's bitwise-equivalence contract compares against
        serial runs of the same inputs).  Returns (ids, n_total, resume):
        the prompt is clamped so prompt + n_total fits ``eng.max_len``,
        and a preempted request's generated prefix is re-prefilled after
        the prompt (the PR-4 resume rule)."""
        n_total = max(1, min(max_new_tokens, req.meta["output_tokens"]))
        resume = req.meta.get("resume_tokens", [])
        prompt_ids = self._tokenizer.encode(req.prompt)[: max(
            1, eng.max_len - n_total)]
        ids = np.concatenate([np.asarray(prompt_ids, np.int64),
                              np.asarray(resume, np.int64)]) \
            if resume else prompt_ids
        return ids, n_total, resume

    def _pop_arrival_aware(self, rep, t: float):
        """Dispatch decision for preemptive real drains: only requests that
        have (virtually) arrived by ``t`` compete — otherwise the best key
        would always dispatch first and nothing could ever preempt.  Jumps
        the clock to the next arrival when the queue is momentarily empty.
        Applies the starvation guard, then the policy key.  One unsorted
        O(n) scan per dispatch."""
        live = rep.queue.live()
        if not live:
            return None, t
        if all(r.arrival > t for r in live):
            t = min(r.arrival for r in live)
        oldest = best = None
        for r in live:
            if r.arrival > t:
                continue
            if oldest is None or (r.arrival, r.req_id) \
                    < (oldest.arrival, oldest.req_id):
                oldest = r
            if best is None or (r.meta["queue_key"], r.req_id) \
                    < (best.meta["queue_key"], best.req_id):
                best = r
        tau = rep.queue.tau
        if tau is not None and (t - oldest.arrival) > tau:
            req = oldest
            req.promoted = True
            rep.queue.stats["promotions"] += 1
        else:
            req = best
        rep.queue.remove(req.req_id)
        rep.queue.stats["dispatched"] += 1
        rep.queue.policy_obj.note_dispatch(req.meta.get("queue_key", 0.0))
        return req, t

    def _best_eligible(self, rep, now: float):
        """Best (key, Request) among queued requests arrived by ``now``.
        Fast path: the heap head is the global best — if it has arrived,
        it is the answer in O(1); otherwise fall back to one unsorted
        scan (polled every fused-decode segment, so no sorting here)."""
        top = rep.queue.peek()
        if top is not None and top[1].arrival <= now:
            return top
        best = None
        for r in rep.queue.live():
            if r.arrival <= now:
                k = r.meta["queue_key"]
                if best is None or k < best[0]:
                    best = (k, r)
        return best

    def _requeue_evicted(self, rep, req, tokens, used_s, key0, level,
                         evict_reason) -> None:
        """Re-enqueue a preempted/demoted request with its resume state,
        using the policy's requeue hooks (custom Policy subclasses can
        override them)."""
        from repro.core.policy import MODE_SRPT
        pol = self.policy_obj
        req.meta["resume_tokens"] = tokens
        req.meta["used_s"] = used_s
        cur_key = req.meta.get("queue_key", key0)
        if evict_reason and evict_reason[0] == "quantum":
            req.meta["mlfq_level"] = level + 1
            new_key = pol.requeue_key(cur_key, used_s)     # demotion
        else:
            base = key0 if pol.mode == MODE_SRPT else cur_key
            new_key = pol.running_key(base, used_s)        # plain eviction
        rep.queue.push_requeue(req, new_key)

    # ---------------------------------------------------------------- stats
    def percentile(self, q: float, klass: Optional[str] = None,
                   attr: str = "sojourn_s",
                   statuses: Sequence[str] = ("ok",)) -> float:
        """Latency percentile over terminal responses.  By default only
        ``ok`` responses count (shed/failed/cancelled requests have no
        meaningful sojourn); pass ``statuses=None`` to pool everything."""
        vals = [getattr(r, attr) for r in self.responses
                if (klass is None or self._klass_of(r) == klass)
                and (statuses is None or r.status in statuses)]
        return float(np.percentile(vals, q)) if vals else float("nan")

    @property
    def ok_responses(self) -> List[CompletionResponse]:
        return [r for r in self.responses if r.status == "ok"]

    def _klass_of(self, resp: CompletionResponse) -> str:
        if resp.klass:
            return resp.klass
        toks = resp.tokens_generated
        return "short" if toks < 200 else ("medium" if toks < 800 else "long")

    @property
    def promotions(self) -> int:
        return sum(rep.queue.stats["promotions"]
                   for rep in self.router.replicas)
