"""The Clairvoyant sidecar: features -> predictor -> SJF queue -> engine.

This is the paper's Figure 2 as framework code.  ``ClairvoyantServer``
fronts N replica engines; each replica is a serial backend with its own
SJFQueue (+ starvation guard).  The multi-replica case routes by predicted
work (core/router.py, beyond paper).  The scheduling policy is a
first-class ``core.policy.Policy`` (registry name or instance): the seed
"fcfs" / "sjf" / "sjf_oracle" plus preemptive SRPT, quantile-aware SJF,
MLFQ and per-tenant fair share — the benchmark ablation is one
constructor argument.  Preemptive policies evict the running request at
the next fused-decode segment boundary (real engines: cancel + resume by
re-prefilling prompt + generated prefix; sim engines: the preemptive DES
in virtual time).

Two backends share the queueing layer:

* the default ``SimEngine`` fleet serves in virtual time from a
  ``ServiceTimeModel`` (thousands of requests, the queueing benchmarks);
* passing ``engines=[RealEngine(...), ...]`` serves each dispatched request
  with an actual fused on-device decode (serving/engine.py) and measured
  wall-clock service times — the end-to-end path the serve benchmark
  exercises (predictor -> SJF queue -> real decode);
* passing ``engines=[BatchedRealEngine(...)]`` drains the queue through
  bounded-concurrency decode lanes under a KV-memory budget
  (``_drain_batched``): back-fill pops via ``SJFQueue.pop_many`` so
  aging promotions are observed between pops, admission blocks on the
  budget in strict policy order, and client disconnects evict their
  lane at the next segment boundary.  Preemptive policies use the
  serial drain (lane eviction by key is future work).

Admission is batched: ``submit_many`` runs feature extraction + GBDT
prediction once across an arrival burst (the PR 1 ``proba_batch`` fast
path); ``submit`` is the single-request convenience wrapper over the same
``_admit``.

The virtual-clock drain loop is event-driven: at every dispatch decision the
queue applies the starvation check, exactly like the Go dispatcher goroutine.
Mid-generation disconnects on a real backend go through ``cancel``: if the
request is currently decoding, the engine's cancel flag stops the fused loop
at the next segment boundary (§3.4 drain semantics).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.policy import get_policy
from repro.core.predictor import Predictor
from repro.core.router import PredictiveRouter
from repro.core.scheduler import Request, SJFQueue
from repro.serving.engine import BatchedRealEngine, RealEngine, SimEngine
from repro.serving.openai_api import CompletionRequest, CompletionResponse
from repro.serving.service_time import ServiceTimeModel, sample_output_tokens
from repro.data.tokenizer import HashTokenizer, approx_token_len


class ClairvoyantServer:
    def __init__(self, *, policy="sjf", tau: Optional[float] = None,
                 n_replicas: int = 1,
                 predictor: Optional[Predictor] = None,
                 service_model: Optional[ServiceTimeModel] = None,
                 engines: Optional[Sequence] = None,
                 seed: int = 0):
        # policy: registry name or Policy instance (core/policy.py)
        self.policy_obj = get_policy(policy)
        self.policy = self.policy_obj.name
        self.predictor = predictor
        self.rng = np.random.default_rng(seed)
        self.service_model = service_model or ServiceTimeModel(
            prefill_tok_per_s=8000.0, decode_tok_per_s=60.0)
        if engines is not None:
            self.engines = list(engines)
            n_replicas = len(self.engines)
        else:
            self.engines = [SimEngine(self.service_model, i)
                            for i in range(n_replicas)]
        self.router = PredictiveRouter(n_replicas, policy=policy, tau=tau)
        self._inflight: Dict[int, CompletionRequest] = {}
        self._decoding: Dict[int, int] = {}     # replica_id -> request_id
        self._disconnected: set = set()         # mid-flight client cancels
        self._oracle_tokens: Dict[int, int] = {}
        self._tokenizer: Optional[HashTokenizer] = None
        self.responses: List[CompletionResponse] = []

    # ------------------------------------------------------------------ API
    def submit(self, req: CompletionRequest, *, arrival: float = 0.0,
               true_output_tokens: Optional[int] = None,
               klass: str = "") -> int:
        """Admit one request.  ``true_output_tokens`` is the oracle ground
        truth (known to the simulator, NOT the scheduler unless policy is
        sjf_oracle)."""
        proba = None
        if self.predictor is not None and self.policy_obj.uses_predictor:
            proba = self.predictor.proba_batch([req.prompt])[0]
        return self._admit(req, proba, arrival, true_output_tokens, klass)

    def submit_many(self, reqs: Sequence[CompletionRequest], *,
                    arrivals: Optional[Sequence[float]] = None,
                    true_output_tokens: Optional[Sequence[int]] = None,
                    klasses: Optional[Sequence[str]] = None) -> List[int]:
        """Admit an arrival burst with ONE batched predictor call.

        Feature extraction + GBDT scoring run once over the whole batch
        (``Predictor.proba_batch``, the PR 1 vectorized admission fast
        path) instead of once per request — ~10x cheaper per request at
        realistic burst sizes.  Returns the chosen replica per request.
        """
        n = len(reqs)
        probas = None
        if self.predictor is not None and self.policy_obj.uses_predictor \
                and n:
            probas = self.predictor.proba_batch([r.prompt for r in reqs])
        return [
            self._admit(
                req,
                None if probas is None else probas[i],
                0.0 if arrivals is None else float(arrivals[i]),
                None if true_output_tokens is None else int(true_output_tokens[i]),
                "" if klasses is None else klasses[i])
            for i, req in enumerate(reqs)
        ]

    def _admit(self, req: CompletionRequest, proba, arrival: float,
               true_output_tokens: Optional[int], klass: str) -> int:
        if true_output_tokens is None:
            true_output_tokens = sample_output_tokens(
                self.rng, klass or "short")
        prompt_toks = approx_token_len(req.prompt)
        p_long = float(proba[2]) if proba is not None else 0.0
        r = Request(req_id=req.request_id, prompt=req.prompt, arrival=arrival,
                    p_long=p_long, klass=klass,
                    true_service=self.service_model.service(
                        prompt_toks, true_output_tokens),
                    tenant=req.tenant,
                    meta={"prompt_tokens": prompt_toks,
                          "output_tokens": true_output_tokens})
        self._inflight[req.request_id] = req
        self._oracle_tokens[req.request_id] = true_output_tokens
        return self.router.route(r, proba=proba, now=arrival)

    def cancel(self, request_id: int) -> bool:
        """Client disconnect: lazy-delete from whichever queue holds it; if
        it is mid-generation on a real engine, flag the fused loop to drain
        at the next segment boundary."""
        for rep in self.router.replicas:
            if rep.queue.cancel(request_id):
                self._inflight.pop(request_id, None)
                return True
        for eng in self.engines:
            # mid-flight on a batched engine: flag the lane; the drain
            # loop evicts it at the next segment boundary
            if isinstance(eng, BatchedRealEngine) \
                    and eng.lane_manager is not None \
                    and eng.lane_manager.lane_of(request_id) is not None:
                self._disconnected.add(request_id)
                return True
        for replica_id, rid in self._decoding.items():
            if rid == request_id:
                eng = self.engines[replica_id]
                if hasattr(eng, "request_cancel"):
                    # distinguishes a disconnect from a preemption eviction:
                    # the drain loop drops disconnected requests instead of
                    # re-enqueueing them
                    self._disconnected.add(request_id)
                    eng.request_cancel()
                    return True
        return False

    def drain(self, max_new_tokens: int = 64) -> List[CompletionResponse]:
        """Run every replica's serial loop to completion.

        SimEngine replicas advance a virtual clock from the service-time
        model; RealEngine replicas actually decode each request (fused loop)
        and feed the measured wall-clock service time into the same clock.
        """
        for rep, eng in zip(self.router.replicas, self.engines):
            if isinstance(eng, BatchedRealEngine) \
                    and not self.policy_obj.preemptive:
                self._drain_batched(rep, eng, max_new_tokens)
            elif isinstance(eng, RealEngine):
                self._drain_real(rep, eng, max_new_tokens)
            else:
                self._drain_sim(rep, eng)
        return self.responses

    def _drain_sim(self, rep, eng) -> None:
        if self.policy_obj.preemptive:
            self._drain_sim_preemptive(rep, eng)
            return
        t = eng.busy_until
        while True:
            req = rep.queue.pop(now=t)
            if req is None:
                break
            t = max(t, req.arrival)
            ttft, service = eng.execute(
                t, req.meta["prompt_tokens"], req.meta["output_tokens"])
            req.start, req.finish = t, t + service
            t += service
            self.router.on_dispatch(rep.replica_id, req, t,
                                    service_estimate=service)
            self.responses.append(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=req.meta["output_tokens"],
                queue_wait_s=req.start - req.arrival,
                service_s=service, ttft_s=req.start - req.arrival + ttft,
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass))

    def _drain_sim_preemptive(self, rep, eng) -> None:
        """Virtual-time drain under a preemptive policy: the replica's
        whole backlog runs through the preemptive DES engine (arrival
        events slice service; evicted work is re-enqueued with the
        policy's requeue key), then responses are emitted in finish
        order.  ``queue_wait_s`` is time to FIRST dispatch."""
        from repro.core.sim_fast import RequestBatch, simulate_batch
        reqs = rep.queue.waiting()
        for r in reqs:                       # drain the queue bookkeeping
            rep.queue.remove(r.req_id)
            rep.queue.stats["dispatched"] += 1
        if not reqs:
            return
        batch = RequestBatch.from_requests(reqs)
        # the engine may still be busy from a previous drain: nothing can
        # start before busy_until, so clamp the simulated arrivals (waits
        # are still reported against the TRUE arrival, like _drain_sim)
        batch.arrival = np.maximum(batch.arrival, eng.busy_until)
        res = simulate_batch(batch, policy=self.policy_obj,
                             tau=rep.queue.tau)
        rep.queue.stats["promotions"] += res.promotions
        rep.queue.stats["preemptions"] += res.preemptions
        order = np.argsort(res.finish, kind="stable")
        for i in order:
            req = reqs[i]
            req.start = float(res.start[i])
            req.finish = float(res.finish[i])
            req.promoted = bool(res.promoted[i])
            service = req.true_service
            ttft = (eng.model.overhead_s + req.meta["prompt_tokens"]
                    / eng.model.prefill_tok_per_s)
            eng.busy_until = max(eng.busy_until, req.finish)
            eng.served += 1
            self.router.on_dispatch(rep.replica_id, req, req.finish,
                                    service_estimate=service)
            self.responses.append(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=req.meta["output_tokens"],
                queue_wait_s=req.start - req.arrival,
                # time in service INCLUDING eviction gaps, so sojourn_s
                # (wait + service) equals finish - arrival exactly
                service_s=req.finish - req.start,
                ttft_s=req.start - req.arrival + ttft,
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass))

    def _drain_real(self, rep, eng: RealEngine, max_new_tokens: int) -> None:
        """Serial wall-clock loop: pop -> tokenize -> fused decode.

        Under a preemptive policy, a queued request whose key strictly
        beats the running one (or, for MLFQ, a running request that
        exhausts its quantum) stops the fused loop at the next segment
        boundary (§3.4 cancellation); the evicted request re-enters the
        queue with its policy requeue key and the tokens generated so
        far, and later resumes by re-prefilling prompt + generated prefix
        (cheap re-prefill: greedy decode makes the resumed sequence
        bitwise-identical to an uninterrupted one).
        """
        import time as _time
        from repro.core.policy import MODE_SRPT
        if self._tokenizer is None:
            self._tokenizer = HashTokenizer(eng.cfg.vocab_size)
        pol = self.policy_obj
        t = eng.busy_until
        while True:
            if pol.preemptive:
                req, t = self._pop_arrival_aware(rep, t)
            else:
                req = rep.queue.pop(now=t)
            if req is None:
                break
            t = max(t, req.arrival)
            ids, n_total, resume = self._prepare_ids(req, eng,
                                                     max_new_tokens)
            n_new = max(1, n_total - len(resume))
            used = req.meta.get("used_s", 0.0)
            key0 = req.meta.get("policy_key0", 0.0)
            level = req.meta.get("mlfq_level", 0)
            evict_reason = []
            cancel_cb = None
            if pol.preemptive:
                wall0 = _time.monotonic()
                # SRPT decays from the ADMISSION key by total service
                # received; level policies carry their current queue key.
                # used/elapsed are wall seconds against model-calibrated
                # keys — an approximation unless the policy's short/long
                # moments are calibrated to this engine.
                base_key = key0 if pol.mode == MODE_SRPT \
                    else req.meta.get("queue_key", key0)

                def cancel_cb():
                    elapsed = _time.monotonic() - wall0
                    best = self._best_eligible(rep, t + elapsed)
                    if best is None:
                        return False
                    quantum = pol.quantum(req.p_long)
                    if (quantum is not None and level == 0
                            and used + elapsed > quantum):
                        evict_reason.append("quantum")
                        return True
                    run_key = pol.running_key(base_key, used + elapsed)
                    if pol.should_preempt(run_key, best[0]):
                        evict_reason.append("preempt")
                        return True
                    return False

            if req.start is None:
                req.start = t                 # first dispatch
            self._decoding[rep.replica_id] = req.req_id
            try:
                out = eng.generate(ids, max_new_tokens=n_new,
                                   cancel_cb=cancel_cb)
            finally:
                self._decoding.pop(rep.replica_id, None)
            service = out["service_s"]
            tokens = list(resume) + list(out["tokens"])
            req.meta.setdefault("ttft_s", out["ttft_s"])
            t += service
            eng.busy_until = t
            if out.get("cancelled"):
                if req.req_id in self._disconnected:
                    self._disconnected.discard(req.req_id)
                    self._inflight.pop(req.req_id, None)
                    continue                  # client disconnect: drop
                if len(tokens) >= n_total:
                    pass                      # done at the boundary anyway
                else:
                    # preemption / demotion: re-enqueue the remaining work
                    self._requeue_evicted(rep, req, tokens, used + service,
                                          key0, level, evict_reason)
                    continue
            total_service = used + service
            req.finish = t
            self.router.on_dispatch(rep.replica_id, req, t,
                                    service_estimate=total_service)
            self.responses.append(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=len(tokens),
                queue_wait_s=req.start - req.arrival,
                service_s=total_service,
                ttft_s=req.start - req.arrival + req.meta["ttft_s"],
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass))

    def _drain_batched(self, rep, eng: BatchedRealEngine,
                       max_new_tokens: int) -> None:
        """Micro-batched wall-clock drain: up to ``eng.n_lanes`` requests
        decode concurrently under the engine's KV budget.

        The queue stays the single source of dispatch order: the engine's
        lane back-fill pulls through :meth:`SJFQueue.pop_many`, so the
        starvation guard is re-evaluated between every pop (a promoted
        waiter takes the next vacant lane even when its key sorts last).
        Admission is memory-aware — a head whose worst-case KV footprint
        does not fit the budget blocks back-fill until lanes retire.
        Client disconnects evict the lane at the next segment boundary
        (per-lane §3.4 semantics).  Preemptive policies use the serial
        ``_drain_real`` path (lane eviction by key is future work); the
        server routes them there before calling this.
        """
        import time as _time
        if self._tokenizer is None:
            self._tokenizer = HashTokenizer(eng.cfg.vocab_size)
        t_base = eng.busy_until
        wall0 = _time.monotonic()

        def now() -> float:
            return t_base + (_time.monotonic() - wall0)

        def source(k: int):
            items = []
            for req in rep.queue.pop_many(k, now=now()):
                ids, n_total, resume = self._prepare_ids(req, eng,
                                                         max_new_tokens)
                items.append({"req_id": req.req_id, "ids": ids,
                              "max_new": max(1, n_total - len(resume)),
                              "tenant": req.tenant,
                              "meta": {"req": req, "resume": list(resume)}})
            return items

        def cancel_check(state) -> bool:
            return state.req_id in self._disconnected

        def on_finish(state, out):
            req = state.meta["req"]
            if out["cancelled"]:
                self._disconnected.discard(req.req_id)
                self._inflight.pop(req.req_id, None)
                return
            tokens = state.meta["resume"] + out["tokens"]
            req.start = max(out["admit_t"], req.arrival)
            req.finish = max(out["finish_t"], req.start)
            req.meta.setdefault("ttft_s", out["ttft_s"])
            self.router.on_dispatch(rep.replica_id, req, req.finish,
                                    service_estimate=out["service_s"])
            self.responses.append(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=len(tokens),
                queue_wait_s=req.start - req.arrival,
                service_s=req.finish - req.start,
                ttft_s=req.start - req.arrival + req.meta["ttft_s"],
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass))

        eng.run_lanes(source, on_finish, cancel_check=cancel_check,
                      now_fn=now)
        eng.busy_until = now()

    def _prepare_ids(self, req, eng, max_new_tokens: int):
        """Token budget + input ids for one dispatch, shared by the serial
        and batched drains (their truncation must match exactly — the
        batched engine's bitwise-equivalence contract compares against
        serial runs of the same inputs).  Returns (ids, n_total, resume):
        the prompt is clamped so prompt + n_total fits ``eng.max_len``,
        and a preempted request's generated prefix is re-prefilled after
        the prompt (the PR-4 resume rule)."""
        n_total = max(1, min(max_new_tokens, req.meta["output_tokens"]))
        resume = req.meta.get("resume_tokens", [])
        prompt_ids = self._tokenizer.encode(req.prompt)[: max(
            1, eng.max_len - n_total)]
        ids = np.concatenate([np.asarray(prompt_ids, np.int64),
                              np.asarray(resume, np.int64)]) \
            if resume else prompt_ids
        return ids, n_total, resume

    def _pop_arrival_aware(self, rep, t: float):
        """Dispatch decision for preemptive real drains: only requests that
        have (virtually) arrived by ``t`` compete — otherwise the best key
        would always dispatch first and nothing could ever preempt.  Jumps
        the clock to the next arrival when the queue is momentarily empty.
        Applies the starvation guard, then the policy key.  One unsorted
        O(n) scan per dispatch."""
        live = rep.queue.live()
        if not live:
            return None, t
        if all(r.arrival > t for r in live):
            t = min(r.arrival for r in live)
        oldest = best = None
        for r in live:
            if r.arrival > t:
                continue
            if oldest is None or (r.arrival, r.req_id) \
                    < (oldest.arrival, oldest.req_id):
                oldest = r
            if best is None or (r.meta["queue_key"], r.req_id) \
                    < (best.meta["queue_key"], best.req_id):
                best = r
        tau = rep.queue.tau
        if tau is not None and (t - oldest.arrival) > tau:
            req = oldest
            req.promoted = True
            rep.queue.stats["promotions"] += 1
        else:
            req = best
        rep.queue.remove(req.req_id)
        rep.queue.stats["dispatched"] += 1
        rep.queue.policy_obj.note_dispatch(req.meta.get("queue_key", 0.0))
        return req, t

    def _best_eligible(self, rep, now: float):
        """Best (key, Request) among queued requests arrived by ``now``.
        Fast path: the heap head is the global best — if it has arrived,
        it is the answer in O(1); otherwise fall back to one unsorted
        scan (polled every fused-decode segment, so no sorting here)."""
        top = rep.queue.peek()
        if top is not None and top[1].arrival <= now:
            return top
        best = None
        for r in rep.queue.live():
            if r.arrival <= now:
                k = r.meta["queue_key"]
                if best is None or k < best[0]:
                    best = (k, r)
        return best

    def _requeue_evicted(self, rep, req, tokens, used_s, key0, level,
                         evict_reason) -> None:
        """Re-enqueue a preempted/demoted request with its resume state,
        using the policy's requeue hooks (custom Policy subclasses can
        override them)."""
        from repro.core.policy import MODE_SRPT
        pol = self.policy_obj
        req.meta["resume_tokens"] = tokens
        req.meta["used_s"] = used_s
        cur_key = req.meta.get("queue_key", key0)
        if evict_reason and evict_reason[0] == "quantum":
            req.meta["mlfq_level"] = level + 1
            new_key = pol.requeue_key(cur_key, used_s)     # demotion
        else:
            base = key0 if pol.mode == MODE_SRPT else cur_key
            new_key = pol.running_key(base, used_s)        # plain eviction
        rep.queue.push_requeue(req, new_key)

    # ---------------------------------------------------------------- stats
    def percentile(self, q: float, klass: Optional[str] = None,
                   attr: str = "sojourn_s") -> float:
        vals = [getattr(r, attr) for r in self.responses
                if klass is None or self._klass_of(r) == klass]
        return float(np.percentile(vals, q)) if vals else float("nan")

    def _klass_of(self, resp: CompletionResponse) -> str:
        if resp.klass:
            return resp.klass
        toks = resp.tokens_generated
        return "short" if toks < 200 else ("medium" if toks < 800 else "long")

    @property
    def promotions(self) -> int:
        return sum(rep.queue.stats["promotions"]
                   for rep in self.router.replicas)
