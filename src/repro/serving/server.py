"""The Clairvoyant sidecar: features -> predictor -> SJF queue -> engine.

This is the paper's Figure 2 as framework code.  ``ClairvoyantServer``
fronts N replica engines; each replica is a serial backend with its own
SJFQueue (+ starvation guard).  The multi-replica case routes by predicted
work (core/router.py, beyond paper).  Policies: "fcfs" | "sjf" |
"sjf_oracle" — the benchmark ablation is one constructor argument.

Two backends share the queueing layer:

* the default ``SimEngine`` fleet serves in virtual time from a
  ``ServiceTimeModel`` (thousands of requests, the queueing benchmarks);
* passing ``engines=[RealEngine(...), ...]`` serves each dispatched request
  with an actual fused on-device decode (serving/engine.py) and measured
  wall-clock service times — the end-to-end path the serve benchmark
  exercises (predictor -> SJF queue -> real decode).

Admission is batched: ``submit_many`` runs feature extraction + GBDT
prediction once across an arrival burst (the PR 1 ``proba_batch`` fast
path); ``submit`` is the single-request convenience wrapper over the same
``_admit``.

The virtual-clock drain loop is event-driven: at every dispatch decision the
queue applies the starvation check, exactly like the Go dispatcher goroutine.
Mid-generation disconnects on a real backend go through ``cancel``: if the
request is currently decoding, the engine's cancel flag stops the fused loop
at the next segment boundary (§3.4 drain semantics).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.predictor import Predictor
from repro.core.router import PredictiveRouter
from repro.core.scheduler import Request, SJFQueue
from repro.serving.engine import RealEngine, SimEngine
from repro.serving.openai_api import CompletionRequest, CompletionResponse
from repro.serving.service_time import ServiceTimeModel, sample_output_tokens
from repro.data.tokenizer import HashTokenizer, approx_token_len


class ClairvoyantServer:
    def __init__(self, *, policy: str = "sjf", tau: Optional[float] = None,
                 n_replicas: int = 1,
                 predictor: Optional[Predictor] = None,
                 service_model: Optional[ServiceTimeModel] = None,
                 engines: Optional[Sequence] = None,
                 seed: int = 0):
        self.policy = policy
        self.predictor = predictor
        self.rng = np.random.default_rng(seed)
        self.service_model = service_model or ServiceTimeModel(
            prefill_tok_per_s=8000.0, decode_tok_per_s=60.0)
        if engines is not None:
            self.engines = list(engines)
            n_replicas = len(self.engines)
        else:
            self.engines = [SimEngine(self.service_model, i)
                            for i in range(n_replicas)]
        self.router = PredictiveRouter(n_replicas, policy=policy, tau=tau)
        self._inflight: Dict[int, CompletionRequest] = {}
        self._decoding: Dict[int, int] = {}     # replica_id -> request_id
        self._oracle_tokens: Dict[int, int] = {}
        self._tokenizer: Optional[HashTokenizer] = None
        self.responses: List[CompletionResponse] = []

    # ------------------------------------------------------------------ API
    def submit(self, req: CompletionRequest, *, arrival: float = 0.0,
               true_output_tokens: Optional[int] = None,
               klass: str = "") -> int:
        """Admit one request.  ``true_output_tokens`` is the oracle ground
        truth (known to the simulator, NOT the scheduler unless policy is
        sjf_oracle)."""
        proba = None
        if self.predictor is not None and self.policy == "sjf":
            proba = self.predictor.proba_batch([req.prompt])[0]
        return self._admit(req, proba, arrival, true_output_tokens, klass)

    def submit_many(self, reqs: Sequence[CompletionRequest], *,
                    arrivals: Optional[Sequence[float]] = None,
                    true_output_tokens: Optional[Sequence[int]] = None,
                    klasses: Optional[Sequence[str]] = None) -> List[int]:
        """Admit an arrival burst with ONE batched predictor call.

        Feature extraction + GBDT scoring run once over the whole batch
        (``Predictor.proba_batch``, the PR 1 vectorized admission fast
        path) instead of once per request — ~10x cheaper per request at
        realistic burst sizes.  Returns the chosen replica per request.
        """
        n = len(reqs)
        probas = None
        if self.predictor is not None and self.policy == "sjf" and n:
            probas = self.predictor.proba_batch([r.prompt for r in reqs])
        return [
            self._admit(
                req,
                None if probas is None else probas[i],
                0.0 if arrivals is None else float(arrivals[i]),
                None if true_output_tokens is None else int(true_output_tokens[i]),
                "" if klasses is None else klasses[i])
            for i, req in enumerate(reqs)
        ]

    def _admit(self, req: CompletionRequest, proba, arrival: float,
               true_output_tokens: Optional[int], klass: str) -> int:
        if true_output_tokens is None:
            true_output_tokens = sample_output_tokens(
                self.rng, klass or "short")
        prompt_toks = approx_token_len(req.prompt)
        p_long = float(proba[2]) if proba is not None else 0.0
        r = Request(req_id=req.request_id, prompt=req.prompt, arrival=arrival,
                    p_long=p_long, klass=klass,
                    true_service=self.service_model.service(
                        prompt_toks, true_output_tokens),
                    tenant=req.tenant,
                    meta={"prompt_tokens": prompt_toks,
                          "output_tokens": true_output_tokens})
        self._inflight[req.request_id] = req
        self._oracle_tokens[req.request_id] = true_output_tokens
        return self.router.route(r, proba=proba, now=arrival)

    def cancel(self, request_id: int) -> bool:
        """Client disconnect: lazy-delete from whichever queue holds it; if
        it is mid-generation on a real engine, flag the fused loop to drain
        at the next segment boundary."""
        for rep in self.router.replicas:
            if rep.queue.cancel(request_id):
                self._inflight.pop(request_id, None)
                return True
        for replica_id, rid in self._decoding.items():
            if rid == request_id:
                eng = self.engines[replica_id]
                if hasattr(eng, "request_cancel"):
                    eng.request_cancel()
                    return True
        return False

    def drain(self, max_new_tokens: int = 64) -> List[CompletionResponse]:
        """Run every replica's serial loop to completion.

        SimEngine replicas advance a virtual clock from the service-time
        model; RealEngine replicas actually decode each request (fused loop)
        and feed the measured wall-clock service time into the same clock.
        """
        for rep, eng in zip(self.router.replicas, self.engines):
            if isinstance(eng, RealEngine):
                self._drain_real(rep, eng, max_new_tokens)
            else:
                self._drain_sim(rep, eng)
        return self.responses

    def _drain_sim(self, rep, eng) -> None:
        t = eng.busy_until
        while True:
            req = rep.queue.pop(now=t)
            if req is None:
                break
            t = max(t, req.arrival)
            ttft, service = eng.execute(
                t, req.meta["prompt_tokens"], req.meta["output_tokens"])
            req.start, req.finish = t, t + service
            t += service
            self.router.on_dispatch(rep.replica_id, req, t,
                                    service_estimate=service)
            self.responses.append(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=req.meta["output_tokens"],
                queue_wait_s=req.start - req.arrival,
                service_s=service, ttft_s=req.start - req.arrival + ttft,
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass))

    def _drain_real(self, rep, eng: RealEngine, max_new_tokens: int) -> None:
        """Serial wall-clock loop: pop -> tokenize -> fused decode."""
        if self._tokenizer is None:
            self._tokenizer = HashTokenizer(eng.cfg.vocab_size)
        t = eng.busy_until
        while True:
            req = rep.queue.pop(now=t)
            if req is None:
                break
            t = max(t, req.arrival)
            n_new = max(1, min(max_new_tokens, req.meta["output_tokens"]))
            ids = self._tokenizer.encode(req.prompt)[: max(
                1, eng.max_len - n_new)]
            self._decoding[rep.replica_id] = req.req_id
            try:
                out = eng.generate(ids, max_new_tokens=n_new)
            finally:
                self._decoding.pop(rep.replica_id, None)
            service = out["service_s"]
            req.start, req.finish = t, t + service
            t += service
            eng.busy_until = t
            self.router.on_dispatch(rep.replica_id, req, t,
                                    service_estimate=service)
            self.responses.append(CompletionResponse(
                request_id=req.req_id, text="",
                tokens_generated=len(out["tokens"]),
                queue_wait_s=req.start - req.arrival,
                service_s=service,
                ttft_s=req.start - req.arrival + out["ttft_s"],
                promoted=req.promoted, replica=rep.replica_id,
                p_long=req.p_long, klass=req.klass))

    # ---------------------------------------------------------------- stats
    def percentile(self, q: float, klass: Optional[str] = None,
                   attr: str = "sojourn_s") -> float:
        vals = [getattr(r, attr) for r in self.responses
                if klass is None or self._klass_of(r) == klass]
        return float(np.percentile(vals, q)) if vals else float("nan")

    def _klass_of(self, resp: CompletionResponse) -> str:
        if resp.klass:
            return resp.klass
        toks = resp.tokens_generated
        return "short" if toks < 200 else ("medium" if toks < 800 else "long")

    @property
    def promotions(self) -> int:
        return sum(rep.queue.stats["promotions"]
                   for rep in self.router.replicas)
