"""Flight recorder, Prometheus metrics, and online ranking-fidelity monitor.

The paper's claims — 0.029 ms predictor latency, 62–96% ranking accuracy,
70–76% short-P50 wins — are measured offline.  This module makes them
observable on live traffic:

* :class:`FlightRecorder` — a lock-cheap ring buffer of *complete* spans
  (both endpoints known at emission time, so there is no open-span state
  to synchronise).  Appends are single ``deque.append`` calls, which are
  atomic under the GIL; worker threads (``InProcessBackend``) and the
  event loop share one recorder without locks.  Exports Chrome/Perfetto
  ``trace_event`` JSON and structured JSONL.
* :class:`MetricsRegistry` + :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` — Prometheus text exposition (format 0.0.4).  Hot
  paths only append raw observations; bucketing happens at scrape time.
  Scrape-time *collector* callbacks export stats the stack already keeps
  (fault_stats, router stats, allocator page states) at zero hot-path
  cost.
* :class:`RankingMonitor` — windowed pairwise concordance of the
  predicted scheduling key against the observed service time (the online
  analogue of the paper's §4.2 pairwise ranking accuracy), plus a
  Long-class calibration-drift stat.  Proxy predictors degrade silently
  under distribution shift (the paper's 52–66% cross-distribution
  regime), so this is the alarm wire.

Span timeline per request (identical schema for live drains and the DES,
so a sim run and a live drain produce comparable flame traces):

    request            (async, per-request track: arrival -> terminal)
      queue_wait       (async: arrival -> dispatch)
      prefill          (replica/lane track)
      decode           (replica/lane track)
        decode_segment (replica/lane track, one per fused segment)

plus ``feature_extract`` / ``predict`` spans when a predictor is
attached and ``route`` instant events from the router.

Everything is stdlib + numpy; nothing here imports the serving stack, so
``core`` modules may call into it without import cycles.
"""

from __future__ import annotations

import json
import math
import re
from bisect import bisect_left
from collections import defaultdict, deque
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Span", "FlightRecorder", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "parse_prometheus", "RankingMonitor",
    "Observability", "record_service_spans", "record_des_trace",
]


# =====================================================================
# Flight recorder
# =====================================================================

# Span kinds: "X" spans live on an exclusive track (a replica or a lane)
# and must nest-or-disjoint; "async" spans (request, queue_wait, and the
# batch-level admission stages) overlap freely across requests and
# export as Perfetto async b/e pairs.
_ASYNC_NAMES = frozenset({"request", "queue_wait", "feature_extract",
                          "predict"})


class Span:
    """A completed span. Plain attribute bag, created only on export."""

    __slots__ = ("name", "req_id", "t0", "t1", "track", "args")

    def __init__(self, name, req_id, t0, t1, track, args):
        self.name = name
        self.req_id = req_id
        self.t0 = t0
        self.t1 = t1
        self.track = track
        self.args = args

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, req={self.req_id}, "
                f"[{self.t0:.6f}, {self.t1:.6f}], track={self.track!r})")


class FlightRecorder:
    """Ring-buffered recorder of completed spans and instant events.

    ``span()`` / ``instant()`` are the only hot-path entry points: each
    is one tuple construction plus one ``deque.append`` (GIL-atomic; no
    locks).  The ring drops the oldest spans once ``capacity`` is
    reached and counts the drops.  Timestamps are caller-supplied
    seconds on whichever clock the drain runs (virtual for the DES and
    sim drains, wall for the sidecar) — the recorder never reads a
    clock, which is what lets sim and live traces share one schema.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._spans: deque = deque(maxlen=self.capacity)
        self._instants: deque = deque(maxlen=self.capacity)
        self.dropped = 0
        # req_id -> latest child-span end; lets the root "request" span
        # cover stragglers (e.g. a requeued dispatch after a cancel).
        self._last_end: Dict[int, float] = {}

    # ------------------------------------------------------------ record
    def span(self, name: str, req_id: int, t0: float, t1: float,
             track: str = "replica0", args: Optional[dict] = None) -> None:
        buf = self._spans
        if len(buf) == buf.maxlen:
            self.dropped += 1
        buf.append((name, req_id, t0, t1, track, args))
        le = self._last_end
        if t1 > le.get(req_id, -math.inf):
            le[req_id] = t1

    def extend(self, spans: Iterable[tuple]) -> None:
        """Bulk append of ``(name, req_id, t0, t1, track, args)`` tuples."""
        buf = self._spans
        le = self._last_end
        for tup in spans:
            if len(buf) == buf.maxlen:
                self.dropped += 1
            buf.append(tup)
            rid, t1 = tup[1], tup[3]
            if t1 > le.get(rid, -math.inf):
                le[rid] = t1

    def instant(self, name: str, req_id: int, t: float,
                track: str = "replica0",
                args: Optional[dict] = None) -> None:
        buf = self._instants
        if len(buf) == buf.maxlen:
            self.dropped += 1
        buf.append((name, req_id, t, track, args))

    def request_span(self, req_id: int, t0: float, t1: float,
                     args: Optional[dict] = None) -> None:
        """Emit the root ``request`` span, stretched to cover any child
        span that outlived the nominal sojourn (requeue/cancel races)."""
        t_last = self._last_end.pop(req_id, t1)
        self.span("request", req_id, t0, max(t1, t_last),
                  track=f"req{req_id}", args=args)

    # ------------------------------------------------------------ access
    def __len__(self) -> int:
        return len(self._spans)

    def spans(self) -> List[Span]:
        return [Span(*tup) for tup in self._spans]

    def instants(self) -> List[tuple]:
        return list(self._instants)

    def spans_for(self, req_id: int) -> List[Span]:
        return [Span(*tup) for tup in self._spans if tup[1] == req_id]

    def span_tree(self, req_id: int) -> Dict[str, object]:
        """The request's span tree: root + children sorted by start."""
        spans = sorted(self.spans_for(req_id), key=lambda s: (s.t0, s.t1))
        roots = [s for s in spans if s.name == "request"]
        children = [s for s in spans if s.name != "request"]
        return {"req_id": req_id, "root": roots[0] if roots else None,
                "roots": roots, "children": children}

    def schema(self) -> List[str]:
        """Sorted set of span names present — the trace's vocabulary."""
        return sorted({tup[0] for tup in self._spans})

    def clear(self) -> None:
        self._spans.clear()
        self._instants.clear()
        self._last_end.clear()
        self.dropped = 0

    # ---------------------------------------------------------- validate
    def validate(self, terminal_ids: Iterable[int],
                 ok_ids: Iterable[int] = (),
                 eps: float = 1e-9) -> List[str]:
        """Trace lifecycle invariants; returns a list of problems.

        * every terminal request has exactly one root ``request`` span
          and every child span lies within the root's bounds (the trace
          mirror of the no-lost-requests terminal gate);
        * requests that finished ``ok`` carry queue_wait/prefill/decode;
        * spans on exclusive (non-async) tracks nest and never overlap.
        """
        problems: List[str] = []
        by_req: Dict[int, List[tuple]] = defaultdict(list)
        by_track: Dict[str, List[tuple]] = defaultdict(list)
        for tup in self._spans:
            by_req[tup[1]].append(tup)
            if tup[0] not in _ASYNC_NAMES:
                by_track[tup[4]].append(tup)

        ok_ids = set(ok_ids)
        for rid in terminal_ids:
            spans = by_req.get(rid, [])
            roots = [s for s in spans if s[0] == "request"]
            if len(roots) != 1:
                problems.append(f"req {rid}: {len(roots)} root spans")
                continue
            _, _, r0, r1, _, _ = roots[0]
            for name, _, t0, t1, _, _ in spans:
                if name == "request":
                    continue
                if t0 < r0 - eps or t1 > r1 + eps:
                    problems.append(
                        f"req {rid}: span {name} [{t0:.6f},{t1:.6f}] "
                        f"outside root [{r0:.6f},{r1:.6f}]")
            if rid in ok_ids:
                names = {s[0] for s in spans}
                for need in ("queue_wait", "prefill", "decode"):
                    if need not in names:
                        problems.append(f"req {rid}: ok but no {need} span")

        for track, spans in by_track.items():
            spans.sort(key=lambda s: (s[2], -s[3]))
            stack: List[tuple] = []           # open (t0, t1) intervals
            for name, rid, t0, t1, _, _ in spans:
                while stack and t0 >= stack[-1][1] - eps:
                    stack.pop()
                if stack and t1 > stack[-1][1] + eps:
                    problems.append(
                        f"track {track}: span {name} (req {rid}) "
                        f"[{t0:.6f},{t1:.6f}] overlaps "
                        f"[{stack[-1][0]:.6f},{stack[-1][1]:.6f}]")
                stack.append((t0, t1))
        return problems

    # ------------------------------------------------------------ export
    def to_perfetto(self) -> Dict[str, object]:
        """Chrome/Perfetto ``trace_event`` JSON (dict; json.dumps-able).

        Exclusive tracks become threads (complete ``"X"`` events);
        async spans become ``"b"``/``"e"`` pairs keyed by request id;
        instants become ``"i"`` events.  ``ts``/``dur`` are microseconds
        on the drain's clock.  Events are sorted by ``ts``.
        """
        tracks = sorted({tup[4] for tup in self._spans
                         if tup[0] not in _ASYNC_NAMES}
                        | {tup[3] for tup in self._instants})
        tid = {tr: i + 1 for i, tr in enumerate(tracks)}
        meta: List[dict] = [{
            "ph": "M", "pid": 0, "name": "process_name", "tid": 0,
            "args": {"name": "clairvoyant"}}]
        for tr, t in tid.items():
            meta.append({"ph": "M", "pid": 0, "tid": t,
                         "name": "thread_name", "args": {"name": tr}})
        events: List[dict] = []
        for name, rid, t0, t1, track, args in self._spans:
            a = dict(args) if args else {}
            a["req_id"] = rid
            if name in _ASYNC_NAMES:
                events.append({"ph": "b", "cat": "request", "id": rid,
                               "name": name, "pid": 0, "tid": 0,
                               "ts": round(t0 * 1e6, 3), "args": a})
                events.append({"ph": "e", "cat": "request", "id": rid,
                               "name": name, "pid": 0, "tid": 0,
                               "ts": round(t1 * 1e6, 3)})
            else:
                events.append({"ph": "X", "cat": "span", "name": name,
                               "pid": 0, "tid": tid[track],
                               "ts": round(t0 * 1e6, 3),
                               "dur": round((t1 - t0) * 1e6, 3),
                               "args": a})
        for name, rid, t, track, args in self._instants:
            a = dict(args) if args else {}
            a["req_id"] = rid
            events.append({"ph": "i", "cat": "event", "name": name,
                           "pid": 0, "tid": tid.get(track, 0), "s": "t",
                           "ts": round(t * 1e6, 3), "args": a})
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": self.dropped}}

    def write_perfetto(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f)

    def jsonl_lines(self) -> List[str]:
        lines = []
        for name, rid, t0, t1, track, args in self._spans:
            lines.append(json.dumps(
                {"type": "span", "name": name, "req_id": rid,
                 "t0": round(t0, 9), "t1": round(t1, 9), "track": track,
                 "args": args or {}}, separators=(",", ":")))
        for name, rid, t, track, args in self._instants:
            lines.append(json.dumps(
                {"type": "instant", "name": name, "req_id": rid,
                 "t": round(t, 9), "track": track, "args": args or {}},
                separators=(",", ":")))
        return lines

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for line in self.jsonl_lines():
                f.write(line + "\n")


def record_service_spans(rec: FlightRecorder, req_id: int, *,
                         start: float, finish: float,
                         arrival: Optional[float] = None,
                         ttft: float = 0.0,
                         out_tokens: Optional[int] = None,
                         segment_tokens: int = 8,
                         max_segments: int = 4,
                         track: str = "replica0",
                         queue_wait: bool = True) -> None:
    """Emit the shared queue_wait/prefill/decode(/segments) span group.

    Used by every drain (DES, sim, real, batched is per-lane but keeps
    the same names), which is what guarantees sim and live traces share
    one schema.  Decode is subdivided into at most ``max_segments``
    synthetic ``decode_segment`` spans sized by ``segment_tokens``
    (live drains overwrite these with measured boundaries by passing
    ``max_segments=0`` and emitting their own).
    """
    spans = []
    if queue_wait and arrival is not None:
        spans.append(("queue_wait", req_id, arrival, start,
                      f"req{req_id}", None))
    t_pref = min(start + max(ttft, 0.0), finish)
    spans.append(("prefill", req_id, start, t_pref, track, None))
    spans.append(("decode", req_id, t_pref, finish, track, None))
    if max_segments > 0 and finish > t_pref:
        n = 1
        if out_tokens is not None and segment_tokens > 0:
            n = max(1, -(-int(out_tokens) // int(segment_tokens)))
        n = min(n, max_segments)
        dt = (finish - t_pref) / n
        t = t_pref
        for i in range(n):
            t2 = finish if i == n - 1 else t + dt
            spans.append(("decode_segment", req_id, t, t2, track,
                          {"seg": i} if i == 0 else None))
            t = t2
    rec.extend(spans)


def record_des_trace(rec: FlightRecorder,
                     arrival: Sequence[float], start: Sequence[float],
                     finish: Sequence[float], req_ids: Sequence[int],
                     *, ttft: Optional[Sequence[float]] = None,
                     out_tokens: Optional[Sequence[int]] = None,
                     replica: Optional[Sequence[int]] = None,
                     statuses: Optional[Sequence[str]] = None,
                     segment_tokens: int = 8,
                     max_segments: int = 4) -> None:
    """Replay a DES result (arrival/start/finish arrays) as spans in
    virtual time — the same schema a live drain records, with zero
    DES inner-loop cost (pure post-processing)."""
    n = len(req_ids)
    for i in range(n):
        rid = int(req_ids[i])
        st, fin = float(start[i]), float(finish[i])
        if not (math.isfinite(st) and math.isfinite(fin)):
            continue
        trk = f"replica{int(replica[i])}" if replica is not None \
            else "replica0"
        otok = out_tokens[i] if out_tokens is not None else None
        record_service_spans(
            rec, rid, arrival=float(arrival[i]), start=st, finish=fin,
            ttft=float(ttft[i]) if ttft is not None else 0.0,
            out_tokens=int(otok) if otok is not None else None,
            segment_tokens=segment_tokens, max_segments=max_segments,
            track=trk)
        status = statuses[i] if statuses is not None else "ok"
        rec.request_span(rid, float(arrival[i]), fin,
                         args={"status": status})


# =====================================================================
# Prometheus metrics (text exposition format 0.0.4)
# =====================================================================

_LABEL_ESC = {"\\": "\\\\", "\n": "\\n", '"': '\\"'}


def _esc(v: str) -> str:
    return "".join(_LABEL_ESC.get(c, c) for c in str(v))


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_esc(v)}"' for k, v in labels)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v)) if v != int(v) else str(int(v))


class Counter:
    """Monotone counter; ``inc`` is a dict add (hot-path safe) and
    ``set_total`` mirrors an externally-kept monotone stat at scrape."""

    kind = "counter"

    def __init__(self, name: str, help_: str):
        self.name = name
        self.help = help_
        self._vals: Dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = tuple(sorted(labels.items()))
        self._vals[key] = self._vals.get(key, 0.0) + amount

    def set_total(self, value: float, **labels) -> None:
        key = tuple(sorted(labels.items()))
        self._vals[key] = float(value)

    def value(self, **labels) -> float:
        return self._vals.get(tuple(sorted(labels.items())), 0.0)

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._vals):
            lines.append(f"{self.name}{_fmt_labels(key)} "
                         f"{_fmt_value(self._vals[key])}")
        return lines


class Gauge(Counter):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = tuple(sorted(labels.items()))
        self._vals[key] = float(value)


_DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                    0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 60.0, 150.0, 600.0)


class Histogram:
    """Prometheus histogram with deferred bucketing.

    ``observe`` appends the raw value to a per-labelset list (one dict
    lookup + one list append — cheap enough for terminal-rate paths);
    cumulative buckets are folded at ``render`` time.
    """

    kind = "histogram"

    def __init__(self, name: str, help_: str,
                 buckets: Sequence[float] = _DEFAULT_BUCKETS):
        self.name = name
        self.help = help_
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._pending: Dict[tuple, list] = defaultdict(list)
        self._counts: Dict[tuple, List[int]] = {}
        self._sum: Dict[tuple, float] = {}
        self._n: Dict[tuple, int] = {}

    def observe(self, value: float, **labels) -> None:
        self._pending[tuple(sorted(labels.items()))].append(value)

    def _fold(self) -> None:
        # observe() may run concurrently from worker threads: snapshot
        # the key list and drain each list by pop() (GIL-atomic).
        nb = len(self.buckets)
        for key in list(self._pending.keys()):
            vals = self._pending[key]
            counts = self._counts.setdefault(key, [0] * (nb + 1))
            while vals:
                v = vals.pop()
                counts[bisect_left(self.buckets, v)] += 1
                self._sum[key] = self._sum.get(key, 0.0) + v
                self._n[key] = self._n.get(key, 0) + 1

    def count(self, **labels) -> int:
        self._fold()
        return self._n.get(tuple(sorted(labels.items())), 0)

    def render(self) -> List[str]:
        self._fold()
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.kind}"]
        for key in sorted(self._counts):
            cum = 0
            base = dict(key)
            for b, c in zip(self.buckets, self._counts[key]):
                cum += c
                lb = tuple(sorted({**base, "le": _fmt_value(b)}.items()))
                lines.append(f"{self.name}_bucket{_fmt_labels(lb)} {cum}")
            cum += self._counts[key][-1]
            lb = tuple(sorted({**base, "le": "+Inf"}.items()))
            lines.append(f"{self.name}_bucket{_fmt_labels(lb)} {cum}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} "
                         f"{_fmt_value(self._sum.get(key, 0.0))}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} "
                         f"{self._n.get(key, 0)}")
        return lines


class MetricsRegistry:
    """Named metrics + scrape-time collector callbacks."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._collectors: List[Callable[[], None]] = []

    def counter(self, name: str, help_: str) -> Counter:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Counter(name, help_)
        return m

    def gauge(self, name: str, help_: str) -> Gauge:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Gauge(name, help_)
        return m

    def histogram(self, name: str, help_: str,
                  buckets: Sequence[float] = _DEFAULT_BUCKETS) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(name, help_, buckets)
        return m

    def add_collector(self, fn: Callable[[], None]) -> None:
        self._collectors.append(fn)

    def render(self) -> str:
        for fn in self._collectors:
            fn()
        lines: List[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(\{[^{}]*\})?"
    r" (NaN|[+-]?Inf|[-+]?[0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?)"
    r"( [0-9]+)?$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> Dict[str, List[tuple]]:
    """Strict-ish parser for exposition format 0.0.4.

    Returns ``{family: [(name, labels_dict, value), ...]}``; raises
    ``ValueError`` on any malformed line (the CI scrape gate).
    """
    families: Dict[str, List[tuple]] = {}
    typed: Dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] in ("HELP", "TYPE"):
                if parts[1] == "TYPE":
                    if len(parts) < 4 or parts[3] not in (
                            "counter", "gauge", "histogram", "summary",
                            "untyped"):
                        raise ValueError(
                            f"line {lineno}: bad TYPE line: {line!r}")
                    typed[parts[2]] = parts[3]
                continue
            raise ValueError(f"line {lineno}: bad comment: {line!r}")
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        name, lab_s, val_s = m.group(1), m.group(2), m.group(3)
        labels = {}
        if lab_s:
            body = lab_s[1:-1].strip().rstrip(",")
            if body:
                consumed = 0
                for lm in _LABEL_RE.finditer(body):
                    labels[lm.group(1)] = lm.group(2)
                    consumed += len(lm.group(0))
                leftover = len(body) - consumed - body.count(",")
                if leftover > 0 or not labels:
                    raise ValueError(
                        f"line {lineno}: malformed labels: {lab_s!r}")
        fam = name
        for suf in ("_bucket", "_sum", "_count", "_total"):
            if name.endswith(suf) and name[:-len(suf)] in typed:
                fam = name[:-len(suf)]
                break
        if fam not in typed and name not in typed:
            raise ValueError(f"line {lineno}: sample {name!r} has no "
                             f"# TYPE declaration")
        val = float(val_s.replace("+Inf", "inf").replace("-Inf", "-inf")
                    .replace("Inf", "inf"))
        families.setdefault(fam, []).append((name, labels, val))
    return families


# =====================================================================
# Online ranking-fidelity monitor
# =====================================================================

class RankingMonitor:
    """Windowed pairwise concordance of predicted key vs observed service.

    Scheduling quality under SJF is bounded by how well the predicted
    key *ranks* requests ("Learning to Rank" framing): for every pair of
    completed requests in the window, does ``sign(key_i - key_j)`` agree
    with ``sign(service_i - service_j)``?  Ties in either dimension are
    excluded (the paper's §4.2 pairwise-accuracy convention).  A
    concordance collapse below ``alert_threshold`` — e.g. an inverted
    or drifted predictor — raises the alert within one window.

    ``record`` is two deque appends; the O(W²) concordance fold runs
    lazily, at most once per ``window // 8`` new samples.
    """

    def __init__(self, window: int = 512, alert_threshold: float = 0.6):
        self.window = int(window)
        self.alert_threshold = float(alert_threshold)
        self._key: deque = deque(maxlen=self.window)
        self._obs: deque = deque(maxlen=self.window)
        self._p_long: deque = deque(maxlen=self.window)
        self._is_long: deque = deque(maxlen=self.window)
        self.recorded = 0
        self._cached: Optional[dict] = None
        self._dirty = 0

    def record(self, key: float, observed_s: float,
               p_long: float = math.nan,
               is_long: Optional[bool] = None) -> None:
        self._key.append(key)
        self._obs.append(observed_s)
        self._p_long.append(p_long)
        self._is_long.append(bool(is_long) if is_long is not None
                             else math.nan)
        self.recorded += 1
        self._dirty += 1

    def concordance(self) -> float:
        """Pairwise agreement in [0, 1]; NaN with < 2 usable pairs."""
        n = len(self._key)
        if n < 2:
            return math.nan
        k = np.asarray(self._key, dtype=np.float64)
        s = np.asarray(self._obs, dtype=np.float64)
        dk = np.sign(k[:, None] - k[None, :])
        ds = np.sign(s[:, None] - s[None, :])
        iu = np.triu_indices(n, k=1)
        dk, ds = dk[iu], ds[iu]
        mask = (dk != 0) & (ds != 0)
        total = int(mask.sum())
        if total == 0:
            return math.nan
        return float((dk[mask] == ds[mask]).sum() / total)

    def long_calibration_drift(self) -> float:
        """|mean predicted P(Long) - observed Long fraction| in-window."""
        p = np.asarray(self._p_long, dtype=np.float64)
        y = np.asarray(self._is_long, dtype=np.float64)
        ok = np.isfinite(p) & np.isfinite(y)
        if not ok.any():
            return math.nan
        return float(abs(p[ok].mean() - y[ok].mean()))

    def snapshot(self) -> dict:
        """Recompute-and-cache; call from scrape paths."""
        conc = self.concordance()
        drift = self.long_calibration_drift()
        alert = bool(len(self._key) >= max(8, self.window // 8)
                     and math.isfinite(conc)
                     and conc < self.alert_threshold)
        self._cached = {
            "window": len(self._key),
            "recorded": self.recorded,
            "concordance": None if math.isnan(conc) else round(conc, 4),
            "long_calibration_drift":
                None if math.isnan(drift) else round(drift, 4),
            "alert": alert,
            "alert_threshold": self.alert_threshold,
        }
        self._dirty = 0
        return self._cached

    def snapshot_cached(self) -> dict:
        """Cheap read for per-response surfacing: refreshes at most
        every ``window // 8`` new samples."""
        if self._cached is None or self._dirty >= max(1, self.window // 8):
            return self.snapshot()
        return self._cached


# =====================================================================
# Bundle
# =====================================================================

class Observability:
    """Recorder + metrics + ranking monitor, passed around as one handle.

    Any component may be None; hot-path call sites gate on the component
    (``rec = obs.recorder; if rec is not None: ...``), so a disabled
    component costs one attribute read and one comparison.
    """

    def __init__(self, recorder: Optional[FlightRecorder] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 ranking: Optional[RankingMonitor] = None):
        self.recorder = recorder
        self.metrics = metrics
        self.ranking = ranking
        self._h_ttft = self._h_sojourn = self._h_wait = None
        self._h_tps = self._h_pred = self._h_accept = None
        self._c_admit = self._c_term = None
        if metrics is not None:
            self._c_admit = metrics.counter(
                "clairvoyant_admissions_total", "Requests admitted")
            self._c_term = metrics.counter(
                "clairvoyant_terminals_total",
                "Terminal responses by status/class")
            self._h_ttft = metrics.histogram(
                "clairvoyant_ttft_seconds", "Time to first token")
            self._h_sojourn = metrics.histogram(
                "clairvoyant_sojourn_seconds",
                "End-to-end sojourn by class")
            self._h_wait = metrics.histogram(
                "clairvoyant_queue_wait_seconds", "Queue wait")
            self._h_tps = metrics.histogram(
                "clairvoyant_tokens_per_second", "Decode throughput",
                buckets=(1, 2, 5, 10, 25, 50, 100, 250, 500, 1000,
                         2500, 5000, 10000, 50000))
            self._h_pred = metrics.histogram(
                "clairvoyant_predictor_latency_seconds",
                "Per-request predictor latency (feature extraction "
                "+ GBDT scoring)",
                buckets=(1e-6, 5e-6, 1e-5, 2.9e-5, 5e-5, 1e-4, 5e-4,
                         1e-3, 5e-3, 0.05))
            self._h_accept = metrics.histogram(
                "clairvoyant_accept_rate",
                "Speculative draft acceptance rate",
                buckets=tuple(i / 10 for i in range(11)))

    @classmethod
    def default(cls, capacity: int = 65536, window: int = 512,
                tracing: bool = True) -> "Observability":
        return cls(recorder=FlightRecorder(capacity) if tracing else None,
                   metrics=MetricsRegistry(),
                   ranking=RankingMonitor(window=window))

    # ------------------------------------------------------- event hooks
    def observe_admission(self, n: int, policy: str) -> None:
        if self._c_admit is not None:
            self._c_admit.inc(n, policy=policy)

    def observe_predict(self, n: int, seconds: float) -> None:
        """Batched admission scored ``n`` requests in ``seconds``; the
        histogram gets one amortised sample per request so batch sizes
        weight the distribution correctly."""
        if self._h_pred is not None and n > 0:
            per = seconds / n
            for _ in range(n):
                self._h_pred.observe(per)

    def observe_terminal(self, resp, arrival: Optional[float]) -> None:
        """One call per terminal response — the `_finish` hook."""
        if self._c_term is not None:
            self._c_term.inc(status=resp.status, klass=resp.klass or "")
            self._h_wait.observe(resp.queue_wait_s)
            if resp.status == "ok":
                self._h_sojourn.observe(resp.sojourn_s,
                                        klass=resp.klass or "")
                if resp.ttft_s is not None:
                    self._h_ttft.observe(resp.ttft_s)
                if resp.service_s > 0 and resp.tokens_generated:
                    self._h_tps.observe(
                        resp.tokens_generated / resp.service_s)
                if resp.accept_rate is not None:
                    self._h_accept.observe(resp.accept_rate)
        mon = self.ranking
        if mon is not None and resp.status == "ok" and resp.service_s > 0:
            mon.record(key=resp.p_long, observed_s=resp.service_s,
                       p_long=resp.p_long,
                       is_long=(resp.klass == "long")
                       if resp.klass else None)
        rec = self.recorder
        if rec is not None and arrival is not None:
            sojourn = resp.queue_wait_s + resp.service_s
            rec.request_span(
                resp.request_id, arrival, arrival + sojourn,
                args={"status": resp.status, "klass": resp.klass,
                      "p_long": round(resp.p_long, 4),
                      "replica": resp.replica})

    # --------------------------------------------------- scrape collector
    def register_server(self, server) -> None:
        """Scrape-time export of stats the server already keeps."""
        if self.metrics is None:
            return
        reg = self.metrics
        g_q = reg.gauge("clairvoyant_queue_depth",
                        "Queued requests per replica")
        g_bk = reg.gauge("clairvoyant_predicted_backlog_seconds",
                         "Predicted-work backlog per replica")
        g_inf = reg.gauge("clairvoyant_inflight",
                          "Admitted, non-terminal requests")
        g_deg = reg.gauge("clairvoyant_degraded",
                          "1 when the predictor is in degraded fallback")
        c_fault = reg.counter("clairvoyant_faults_total",
                              "Fault-layer events by kind")
        c_route = reg.counter("clairvoyant_router_total",
                              "Router events by kind")
        g_rank = reg.gauge("clairvoyant_ranking_concordance",
                           "Windowed pairwise ranking concordance")
        g_rwin = reg.gauge("clairvoyant_ranking_window",
                           "Samples in the ranking window")
        g_ralert = reg.gauge("clairvoyant_ranking_alert",
                             "1 when ranking concordance is below "
                             "the alert threshold")
        g_drift = reg.gauge("clairvoyant_long_calibration_drift",
                            "|mean P(Long) - observed Long fraction|")
        g_drop = reg.gauge("clairvoyant_trace_dropped_spans",
                           "Spans dropped by the flight-recorder ring")

        def collect():
            for r in server.router.replicas:
                lab = {"replica": str(r.replica_id)}
                g_q.set(len(r.queue), **lab)
                g_bk.set(r.predicted_backlog, **lab)
            g_inf.set(len(server._inflight))
            g_deg.set(1.0 if server.degraded else 0.0)
            for k, v in server.fault_stats.items():
                c_fault.set_total(v, kind=k)
            for k, v in server.router.stats.items():
                c_route.set_total(v, kind=k)
            mon = self.ranking
            if mon is not None:
                snap = mon.snapshot()
                if snap["concordance"] is not None:
                    g_rank.set(snap["concordance"])
                g_rwin.set(snap["window"])
                g_ralert.set(1.0 if snap["alert"] else 0.0)
                if snap["long_calibration_drift"] is not None:
                    g_drift.set(snap["long_calibration_drift"])
            if self.recorder is not None:
                g_drop.set(self.recorder.dropped)

        reg.add_collector(collect)

    def register_engines(self, engines) -> None:
        """Export lane occupancy / dead steps / accept rate / page states
        from engine ``stats()`` dicts at scrape time."""
        if self.metrics is None:
            return
        reg = self.metrics
        g_lane = reg.gauge("clairvoyant_lane_occupancy",
                           "Busy decode lanes per replica")
        c_dead = reg.counter("clairvoyant_dead_steps_total",
                             "Lane-steps wasted on dead lanes")
        g_acc = reg.gauge("clairvoyant_speculative_accept_rate",
                          "Cumulative draft-token acceptance rate")
        g_pages = reg.gauge("clairvoyant_pages",
                            "KV pool pages by state (free/cached/held)")

        def collect():
            for eng in engines:
                stats_fn = getattr(eng, "engine_stats", None) \
                    or getattr(eng, "stats_dict", None)
                st = stats_fn() if callable(stats_fn) else {}
                if not isinstance(st, dict):
                    continue
                rid = str(st.get("replica", getattr(eng, "replica_id", 0)))
                lab = {"replica": rid}
                if "lanes_busy" in st:
                    g_lane.set(st["lanes_busy"], **lab)
                if "dead_steps" in st:
                    c_dead.set_total(st["dead_steps"], **lab)
                if st.get("accept_rate") is not None:
                    g_acc.set(st["accept_rate"], **lab)
                pages = st.get("pages")
                if isinstance(pages, dict):
                    for state in ("free", "cached", "held"):
                        if state in pages:
                            g_pages.set(pages[state], state=state, **lab)

        reg.add_collector(collect)

    def render_metrics(self) -> str:
        if self.metrics is None:
            return ""
        return self.metrics.render()
