"""Standalone Prometheus scrape endpoint.

The sidecar already serves ``GET /metrics`` on its API port; this tiny
asyncio server exposes the same registry on a *separate* port
(``launch.sidecar --metrics-port``) so operators can firewall the scrape
surface away from the request path — the usual fleet convention.

    srv = MetricsServer(observability, port=9090)
    await srv.start()
    ...
    await srv.stop()

Routes: ``GET /metrics`` (text exposition 0.0.4) and ``GET /`` (a
one-line pointer).  Anything else is 404.  Stdlib-only.
"""

from __future__ import annotations

import asyncio
from typing import Optional

from repro.serving.observability import Observability

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Minimal HTTP/1.1 close-after-response scrape server."""

    def __init__(self, obs: Observability, host: str = "127.0.0.1",
                 port: int = 0):
        self.obs = obs
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            try:
                line = await asyncio.wait_for(reader.readline(), timeout=5.0)
            except asyncio.TimeoutError:
                return
            parts = line.decode("latin-1", "replace").split()
            path = parts[1] if len(parts) >= 2 else "/"
            # drain headers
            while True:
                h = await asyncio.wait_for(reader.readline(), timeout=5.0)
                if h in (b"\r\n", b"\n", b""):
                    break
            if path.split("?")[0] == "/metrics":
                body = self.obs.render_metrics().encode()
                status, ctype = "200 OK", CONTENT_TYPE
            elif path == "/":
                body = b"clairvoyant metrics: scrape /metrics\n"
                status, ctype = "200 OK", "text/plain; charset=utf-8"
            else:
                body = b"not found\n"
                status, ctype = "404 Not Found", "text/plain; charset=utf-8"
            writer.write((f"HTTP/1.1 {status}\r\n"
                          f"Content-Type: {ctype}\r\n"
                          f"Content-Length: {len(body)}\r\n"
                          f"Connection: close\r\n\r\n").encode())
            writer.write(body)
            await writer.drain()
        except (ConnectionError, asyncio.TimeoutError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except Exception:
                pass
