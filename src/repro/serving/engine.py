"""Replica engine: the serial backend behind the admission layer.

Two execution modes share one interface:

* ``RealEngine`` — jitted prefill + fused on-device greedy decode of an
  actual LM (used by the examples, the serve benchmark and integration tests
  with reduced configs on CPU; on TPU the same class serves full configs
  with the Pallas decode kernels swapped in via kernels/ops.py);
* ``SimEngine`` — virtual-clock engine using a ServiceTimeModel (used by the
  queueing benchmarks, where thousands of requests are served);
* ``BatchedRealEngine`` — bounded-concurrency micro-batching over
  ``RealEngine``'s model: ``n_lanes`` concurrent requests under a
  KV-memory budget (serving/batching.py), lane-batched segment decode
  (serving/generate.py ``LaneDecoder``), retire-and-back-fill at segment
  boundaries.  Per-request greedy tokens stay bitwise-equal to serial
  runs.

The first two are strictly serial: one request in flight per replica — the
regime the paper targets (§2.3).  Disconnect semantics per §3.4:
cancellation while queued removes the heap entry (lazy); cancellation
mid-generation stops the fused loop at the next segment boundary
(``request_cancel``; per-lane eviction on the batched engine), draining the
response to free the dispatch slot within ``segment_len`` tokens.

``RealEngine`` generation path (PR 3):

* **Bucketed prefill** — prompts are right-padded to a small geometric set
  of lengths (powers of two up to ``max_len``; see
  ``generate.geometric_buckets``), so a mixed-length admission stream
  triggers O(log max_len) jit compiles instead of one per distinct prompt
  length.  The true ``prompt_len`` rides into the jitted prefill as a
  dynamic scalar: logits are gathered at ``prompt_len - 1`` and the cache
  fill level is reset to ``prompt_len`` (models/model.py).  Padded prefill
  is only bit-safe for causal-local stacks, so bucketing engages when the
  block pattern is pure attention and falls back to exact lengths (the seed
  behavior) otherwise.
* **Ring-buffer KV cache** — caches hold ``max_len`` slots; decode writes
  step ``t`` at slot ``t % max_len`` (models/attention.py), so capacity is
  an attention-window bound, never a per-request reallocation.
* **Fused decode** — ``generate`` drives ``serving.generate.FusedDecoder``:
  segments of ``segment_len`` tokens run in one jitted ``lax.while_loop``
  with the EOS/length stop on device and the caches donated in place; the
  host syncs once per segment.  The seed per-token Python loop is retained
  as ``generate_reference`` — the bitwise token-sequence equivalence oracle
  (tests/test_generate.py), matching the PR 1/PR 2 oracle pattern.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.serving.service_time import ServiceTimeModel


class SimEngine:
    """Virtual-time serial backend."""

    def __init__(self, model: ServiceTimeModel, replica_id: int = 0):
        self.model = model
        self.replica_id = replica_id
        self.busy_until = 0.0
        self.served = 0

    def engine_stats(self) -> dict:
        """Wire-facing stats snapshot (sidecar /healthz, /metrics)."""
        return {"replica": self.replica_id, "served": self.served}

    def execute(self, start: float, prompt_tokens: int,
                output_tokens: int) -> tuple[float, float]:
        """Returns (ttft_s, service_s); advances the virtual clock."""
        service = self.model.service(prompt_tokens, output_tokens)
        ttft = self.model.overhead_s + prompt_tokens / self.model.prefill_tok_per_s
        self.busy_until = start + service
        self.served += 1
        return ttft, service


# Padded (bucketed) prefill is only used when every block's per-position
# state is causal-local; SSM/xLSTM recurrences fold pad tokens into their
# state and MoE capacity routing lets pad tokens evict real ones.
_BUCKET_SAFE_KINDS = ("attn",)


class RealEngine:
    """Actual LM decode on device (reduced configs on this CPU container)."""

    def __init__(self, cfg, params=None, replica_id: int = 0, seed: int = 0,
                 max_len: int = 256, segment_len: int = 16,
                 draft_cfg=None, draft_params=None, draft_k: int = 0,
                 draft_seed: int = 0):
        import jax
        import jax.numpy as jnp
        from repro.models.model import LM
        from repro.serving.generate import (FusedDecoder,
                                            SpeculativeDecoder,
                                            geometric_buckets)

        self.cfg = cfg
        self.lm = LM(cfg)
        self.replica_id = replica_id
        self.max_len = max_len
        self.segment_len = segment_len
        self.params = params if params is not None \
            else self.lm.init(jax.random.key(seed))
        self.busy_until = 0.0
        self.served = 0
        self._cancel = False
        # optional serving.faults.FaultInjector: polled at fused-decode
        # segment boundaries (same join points as cancellation), where an
        # injected crash surfaces as an EngineCrash raise out of generate
        self.fault_injector = None
        # optional serving.observability.FlightRecorder: the batched lane
        # loop stamps per-lane prefill/decode/decode_segment spans on it
        # (timestamps from the caller's now_fn, so virtual clocks work)
        self.recorder = None
        self._pending_items: list = []

        self._bucketing = all(k in _BUCKET_SAFE_KINDS
                              for k in cfg.block_pattern)
        self.buckets = geometric_buckets(max_len) if self._bucketing else ()
        # One jit; retraces once per bucket shape (prompt_len is dynamic).
        self._prefill = jax.jit(
            lambda p, toks, plen: self.lm.prefill(
                p, {"tokens": toks}, pad_to=max_len, prompt_len=plen))
        self._decode = jax.jit(self.lm.decode_step)       # oracle path
        self._decoders = {segment_len: FusedDecoder(self.lm, max_len,
                                                    segment_len)}
        # speculative decoding (draft_k >= 1 + a draft config): the small
        # draft model proposes token chains the target verifies in one
        # multi-position forward.  K=0 keeps the plain fused path even
        # when a draft config is supplied.
        self.draft_cfg = draft_cfg
        self.draft_k = int(draft_k)
        self.speculative = draft_cfg is not None and self.draft_k > 0
        self.draft_lm = None
        self.draft_params = None
        if self.speculative:
            if not self._bucketing:
                raise ValueError(
                    "speculative decoding needs a pure-attention stack "
                    f"(got pattern {cfg.block_pattern}): the verify "
                    "forward is an attention-cache operation")
            if not all(k in _BUCKET_SAFE_KINDS
                       for k in draft_cfg.block_pattern):
                raise ValueError(
                    "draft model needs a pure-attention stack "
                    f"(got pattern {draft_cfg.block_pattern})")
            self.draft_lm = LM(draft_cfg)
            self.draft_params = draft_params if draft_params is not None \
                else self.draft_lm.init(jax.random.key(draft_seed))
            self._draft_prefill = jax.jit(
                lambda p, toks, plen: self.draft_lm.prefill(
                    p, {"tokens": toks}, pad_to=max_len, prompt_len=plen))
            self._spec_decoder = SpeculativeDecoder(
                self.lm, self.draft_lm, max_len, self.draft_k)

    # ---------------------------------------------------------------- admin
    def request_cancel(self) -> None:
        """§3.4 mid-generation disconnect: the fused loop observes this flag
        at the next segment boundary and drains."""
        self._cancel = True

    def engine_stats(self) -> dict:
        """Wire-facing stats snapshot (sidecar /healthz, /metrics)."""
        return {"replica": self.replica_id, "served": self.served,
                "speculative": self.speculative}

    def _decoder(self, segment_len: int):
        dec = self._decoders.get(segment_len)
        if dec is None:
            from repro.serving.generate import FusedDecoder
            dec = FusedDecoder(self.lm, self.max_len, segment_len)
            self._decoders[segment_len] = dec
        return dec

    # -------------------------------------------------------------- prefill
    def _run_prefill(self, prompt_ids: np.ndarray, prefill=None,
                     params=None):
        """Bucket-pad + prefill.  Returns (last_logits, caches, prompt_len).
        ``prefill``/``params`` override the target model's (the draft
        model prefills through the same bucketing so its cache rows are
        laid out identically)."""
        import jax.numpy as jnp
        from repro.serving.generate import bucket_for
        ids = np.asarray(prompt_ids, np.int32).reshape(-1)
        plen = len(ids)
        if plen < 1:
            raise ValueError("empty prompt: prefill needs >= 1 token "
                             "(dynamic_slice would silently clamp to 0)")
        if self._bucketing:
            bucket = bucket_for(plen, self.buckets)
        else:
            bucket = plen                     # exact length (seed behavior)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = ids
        logits, caches = (prefill or self._prefill)(
            self.params if params is None else params,
            jnp.asarray(toks), jnp.asarray(plen, jnp.int32))
        return logits, caches, plen

    def _run_prefill_group(self, ids_list, pad_rows: Optional[int] = None,
                           prefill=None, params=None):
        """One padded prefill for prompts sharing a bucket (lane
        admission batches).  Returns (last_logits (k, V), caches with
        per-row fill levels, plens).  Rows are padded exactly as their
        solo bucketed prefill would be, so per-row results match the
        serial path; callers group by bucket before calling.

        The batch axis is padded to ``pad_rows`` (dummy single-token
        rows, sliced off before returning): back-fill group sizes vary
        per drain, and compiling one prefill program per exact (k,
        bucket) pair would pay a jit compile mid-drain for every new
        combination.  The batched engine pads every group to its lane
        count — ONE program per bucket, like the serial engine — trading
        <= lanes x of a ~ms prefill for never compiling (~0.7 s) on the
        serving path.  Default (``pad_rows=None``): the next power of
        two."""
        import jax
        import jax.numpy as jnp
        from repro.serving.generate import bucket_for
        ids_list = [np.asarray(i, np.int32).reshape(-1) for i in ids_list]
        plens = [len(i) for i in ids_list]
        if min(plens) < 1:
            raise ValueError("empty prompt in prefill group")
        if self._bucketing:
            buckets = {bucket_for(p, self.buckets) for p in plens}
        else:
            buckets = set(plens)           # exact lengths (seed behavior)
        if len(buckets) != 1:
            raise ValueError(f"prefill group spans buckets {buckets}")
        bucket = buckets.pop()
        k = len(ids_list)
        if pad_rows is not None:
            if k > pad_rows:
                raise ValueError(f"group of {k} exceeds pad_rows {pad_rows}")
            kp = pad_rows
        else:
            kp = 1
            while kp < k:
                kp *= 2
        toks = np.zeros((kp, bucket), np.int32)
        for r, ids in enumerate(ids_list):
            toks[r, :len(ids)] = ids
        logits, caches = (prefill or self._prefill)(
            self.params if params is None else params, jnp.asarray(toks),
            jnp.asarray(plens + [1] * (kp - k), jnp.int32))
        if kp != k:
            logits = logits[:k]
            caches = jax.tree.map(lambda x: x[:, :k], caches)
        return logits, caches, plens

    # ------------------------------------------------------------- generate
    def generate(self, prompt_ids: np.ndarray, max_new_tokens: int = 32,
                 eos_id: Optional[int] = None, cancel_cb=None,
                 segment_len: Optional[int] = None, on_segment=None) -> dict:
        """Fused greedy decode.  prompt_ids: (S,) ints.

        Returns {"tokens", "ttft_s", "service_s", "cancelled", "segments"}.
        ``cancel_cb`` (optional nullary) is polled with the engine's own
        cancel flag between scan segments.  ``on_segment(new_tokens)``
        streams tokens out at each segment boundary (the sidecar's SSE
        flush points — see :meth:`FusedDecoder.decode`).
        """
        self._cancel = False
        t0 = time.monotonic()
        logits, caches, plen = self._run_prefill(prompt_ids)
        tok = int(np.argmax(np.asarray(logits)[0]))
        ttft = time.monotonic() - t0

        def cancelled():
            if self.fault_injector is not None:
                # may raise EngineCrash: the mid-generation crash fires at
                # the segment boundary, exactly where a cancel would land
                self.fault_injector.poll_segment(self.replica_id)
            return self._cancel or (cancel_cb is not None and cancel_cb())

        if self.speculative:
            _, dcaches, _ = self._run_prefill(
                prompt_ids, prefill=self._draft_prefill,
                params=self.draft_params)
            out = self._spec_decoder.decode(
                self.params, self.draft_params, caches, dcaches, tok, plen,
                max_new_tokens, eos_id=eos_id, cancel_check=cancelled,
                on_segment=on_segment)
        else:
            dec = self._decoder(segment_len or self.segment_len)
            out = dec.decode(self.params, caches, tok, plen, max_new_tokens,
                             eos_id=eos_id, cancel_check=cancelled,
                             on_segment=on_segment)
        self.served += 1
        self._cancel = False
        res = {"tokens": out["tokens"], "ttft_s": ttft,
               "service_s": time.monotonic() - t0,
               "cancelled": out["cancelled"], "segments": out["segments"]}
        if self.speculative:
            res["drafted"] = out["drafted"]
            res["accepted"] = out["accepted"]
            res["accept_rate"] = out["accepted"] / out["drafted"] \
                if out["drafted"] else None
        return res

    def generate_batch(self, prompts, max_new_tokens=32,
                       eos_id: Optional[int] = None) -> list:
        """Serial fallback so both engine classes share one batch API."""
        maxes = self._per_request_budgets(prompts, max_new_tokens)
        return [self.generate(ids, max_new_tokens=m, eos_id=eos_id)
                for ids, m in zip(prompts, maxes)]

    @staticmethod
    def _per_request_budgets(prompts, max_new_tokens) -> list:
        if np.isscalar(max_new_tokens):
            return [int(max_new_tokens)] * len(prompts)
        return [int(m) for m in max_new_tokens]

    def generate_reference(self, prompt_ids: np.ndarray,
                           max_new_tokens: int = 32,
                           eos_id: Optional[int] = None) -> dict:
        """Seed per-token Python loop (one host sync + dispatch per token).

        Kept in-tree as the equivalence oracle for the fused loop: same
        prefill, same stop-condition order, so token sequences must match
        bitwise (tests/test_generate.py).
        """
        import jax.numpy as jnp
        t0 = time.monotonic()
        logits, caches, plen = self._run_prefill(prompt_ids)
        tok = int(np.argmax(np.asarray(logits)[0]))
        ttft = time.monotonic() - t0
        out = [tok]
        for _ in range(max_new_tokens - 1):
            if eos_id is not None and tok == eos_id:
                break
            if plen + len(out) >= self.max_len:
                break
            logits, caches = self._decode(
                self.params, caches, {"tokens": jnp.full((1, 1), tok, jnp.int32)})
            tok = int(np.argmax(np.asarray(logits)[0]))
            out.append(tok)
        self.served += 1
        return {"tokens": out, "ttft_s": ttft,
                "service_s": time.monotonic() - t0}


class BatchedRealEngine(RealEngine):
    """Bounded-concurrency real decode: ``n_lanes`` concurrent requests
    under a KV-memory budget (serving/batching.py).

    Each lane is an independent ring-buffer cache stacked on a leading
    lane axis; one fused segment steps every live lane together
    (``serving.generate.LaneDecoder``), and segment boundaries are the
    join points where finished lanes retire and the manager back-fills
    from the caller's queue by re-prefilling into the vacant cache slot —
    continuous micro-batching with static cache shapes (no recompiles as
    the batch composition changes).

    Equivalence contract: under greedy decode, each request's token
    sequence is bitwise-equal to an independent ``generate_reference``
    run — including requests admitted mid-stream by back-fill
    (tests/test_batching.py).

    Admission is memory-aware and strictly policy-ordered: the next
    request (in the order the ``source`` yields them) is admitted only
    when its worst-case KV footprint — ``min(max_len, prompt + max_new)``
    ring slots at ``kv_bytes_per_token(cfg)`` — fits the budget; a head
    that does not fit blocks until lanes retire (no smaller request may
    bypass it).  ``budget_bytes=None`` sizes the budget to exactly
    ``n_lanes`` full rings, i.e. lane-count-limited.
    """

    def __init__(self, cfg, params=None, replica_id: int = 0, seed: int = 0,
                 max_len: int = 256, segment_len: int = 16,
                 n_lanes: int = 4, budget_bytes: Optional[int] = None,
                 draft_cfg=None, draft_params=None, draft_k: int = 0,
                 draft_seed: int = 0):
        from repro.serving.batching import kv_bytes_per_token
        from repro.serving.generate import (LaneDecoder,
                                            SpeculativeLaneDecoder)
        super().__init__(cfg, params=params, replica_id=replica_id,
                         seed=seed, max_len=max_len, segment_len=segment_len,
                         draft_cfg=draft_cfg, draft_params=draft_params,
                         draft_k=draft_k, draft_seed=draft_seed)
        self.n_lanes = int(n_lanes)
        self._bytes_per_token = kv_bytes_per_token(cfg)
        # a speculative lane carries the draft model's ring KV alongside
        # the target's — real memory, charged against the same budget
        self._draft_bytes_per_token = kv_bytes_per_token(draft_cfg) \
            if self.speculative else 0
        lane_bpt = self._bytes_per_token + self._draft_bytes_per_token
        self.budget_bytes = int(budget_bytes) if budget_bytes is not None \
            else self.n_lanes * max_len * max(1, lane_bpt)
        if self.speculative:
            self._lane_decoder = SpeculativeLaneDecoder(
                self.lm, self.draft_lm, self.draft_params, max_len,
                self.n_lanes, segment_len, draft_k=self.draft_k)
            # paged growth must cover every verify position a segment can
            # write: rounds x (K+1) slots, vs segment_len serial steps
            self._growth_span = self._lane_decoder.rounds * (self.draft_k + 1)
        else:
            self._lane_decoder = LaneDecoder(self.lm, max_len, self.n_lanes,
                                             segment_len)
            self._growth_span = segment_len
        self.lane_manager = None       # the most recent run's manager/stats
        self.dead_steps = 0            # lane-steps burned on stopped lanes
        self.drafted_total = 0         # draft positions proposed (this run)
        self.accepted_total = 0        # draft positions accepted (this run)

    def take_pending(self) -> list:
        """Drain the popped-but-not-admitted work items of the most recent
        ``run_lanes`` call (crash recovery: these left the caller's queue
        but never reached a lane, so an aborted run would lose them)."""
        items, self._pending_items = list(self._pending_items), []
        return items

    @property
    def accept_rate(self) -> Optional[float]:
        """Aggregate draft acceptance over the most recent run, or None
        before any draft position was proposed."""
        return self.accepted_total / self.drafted_total \
            if self.drafted_total else None

    def engine_stats(self) -> dict:
        """Wire-facing stats: adds dead-step and speculation accounting
        plus live lane occupancy (sidecar /healthz, /metrics)."""
        st = super().engine_stats()
        mgr = self.lane_manager
        st.update(dead_steps=self.dead_steps, lanes=self.n_lanes,
                  lanes_busy=len(mgr.busy_lanes()) if mgr is not None
                  else 0, drafted=self.drafted_total,
                  accepted=self.accepted_total,
                  accept_rate=self.accept_rate)
        return st

    def _accumulate_spec(self, mgr, dec) -> None:
        """Post-segment speculation accounting: per-lane and aggregate
        drafted/accepted counters, and the dead-step extension — wasted
        draft positions (drafted - accepted) burn lane time exactly like
        the masked compute of a stopped lane, so they fold into the same
        ``dead_steps`` figure the PR-5 trade-off reports."""
        if not self.speculative:
            return
        drafted, accepted = dec.last_drafted, dec.last_accepted
        for lane in mgr.busy_lanes():
            st = mgr.lanes[lane]
            st.drafted += int(drafted[lane])
            st.accepted += int(accepted[lane])
        d, a = int(drafted.sum()), int(accepted.sum())
        self.drafted_total += d
        self.accepted_total += a
        self.dead_steps += d - a
        mgr.stats["drafted"] = self.drafted_total
        mgr.stats["accepted"] = self.accepted_total
        mgr.stats["accept_rate"] = self.accept_rate

    # ----------------------------------------------------------- batch API
    def generate_batch(self, prompts, max_new_tokens=32,
                       eos_id: Optional[int] = None) -> list:
        """Decode a request list through the lanes; results in input order.

        ``max_new_tokens`` is a scalar or per-request sequence.  Returns
        one dict per request: {"tokens", "ttft_s", "service_s",
        "cancelled", "lane", "evictions"}.
        """
        maxes = self._per_request_budgets(prompts, max_new_tokens)
        n = len(prompts)
        results: list = [None] * n
        cursor = {"i": 0}

        def source(k: int) -> list:
            out = []
            while k > 0 and cursor["i"] < n:
                i = cursor["i"]
                cursor["i"] += 1
                out.append({"req_id": i, "ids": prompts[i],
                            "max_new": maxes[i], "meta": {"i": i}})
                k -= 1
            return out

        def on_finish(state, res):
            results[state.meta["i"]] = res

        self.run_lanes(source, on_finish, eos_id=eos_id)
        return results

    # -------------------------------------------------- lane-loop hook points
    # The paged engine (PagedBatchedEngine) reuses the whole run_lanes loop
    # and specializes only these: manager construction, the admission
    # check/commit (prefix-aware in pages), the prefill-and-insert step
    # (page scatter + extend prefill), the pre-segment hook (page growth /
    # preemption) and the post-release hook (block-table scrub).
    def _new_manager(self):
        from repro.serving.batching import KVBudget, LaneManager
        return LaneManager(self.n_lanes, KVBudget(self.budget_bytes),
                           self._bytes_per_token
                           + self._draft_bytes_per_token, self.max_len)

    def _head_fits(self, mgr, item, ids) -> bool:
        return mgr.can_admit(len(ids), item["max_new"])

    def _admit_item(self, mgr, lane: int, item, ids, t_admit, backfill: bool):
        return mgr.admit(lane, req_id=item["req_id"], prompt_len=len(ids),
                         max_new=item["max_new"],
                         tenant=item.get("tenant", "default"),
                         admit_t=t_admit, meta=item.get("meta"),
                         backfill=backfill)

    def _post_insert(self, group, first, plens, now, tok, plen, produced,
                     max_new, active) -> None:
        """Shared host-side bookkeeping once a claim group is prefilled
        and inserted: per-lane counters + the first (prefill) token."""
        for r, (st, lane, ids, mx) in enumerate(group):
            st.prompt_len = plens[r]
            st.ttft_s = now() - st.admit_t
            st.tokens = [int(first[r])]
            tok[lane] = int(first[r])
            plen[lane] = plens[r]
            produced[lane] = 1
            max_new[lane] = mx
            active[lane] = True

    def _insert_draft(self, dec, caches, lanes, ids_list):
        """Speculative only: prefill the draft model over the same ids
        (identical bucketing, so cache rows lay out like the target's)
        and drop the rows into the lanes' draft caches.  Resumed and
        prefix-hit requests take this same full prefill — the draft has
        no prefix cache, and its state only ever affects acceptance rate,
        never emitted tokens."""
        if not self.speculative:
            return caches
        _, dcache, _ = self._run_prefill_group(
            ids_list, pad_rows=self.n_lanes, prefill=self._draft_prefill,
            params=self.draft_params)
        return dec.insert_draft(caches, lanes, dcache)

    def _prefill_claims(self, mgr, dec, caches, claims, now, tok, plen,
                        produced, max_new, active):
        """Prefill admitted claims per bucket group (rows pad exactly as
        their solo prefill would, so per-lane results match the serial
        path bitwise) — one jit call + one lane insert per group."""
        from repro.serving.generate import bucket_for

        def bucket_of(n):
            return bucket_for(n, self.buckets) if self._bucketing else n
        groups: dict = {}
        for claim in claims:
            groups.setdefault(bucket_of(len(claim[2])), []).append(claim)
        for group in groups.values():
            logits, pcache, plens = self._run_prefill_group(
                [ids for _, _, ids, _ in group], pad_rows=self.n_lanes)
            first = np.argmax(np.asarray(logits), axis=-1)
            caches = dec.insert_lanes(
                caches, [lane for _, lane, _, _ in group], pcache)
            caches = self._insert_draft(
                dec, caches, [lane for _, lane, _, _ in group],
                [ids for _, _, ids, _ in group])
            self._post_insert(group, first, plens, now, tok, plen,
                              produced, max_new, active)
        return caches

    def _boundary_reset(self) -> None:
        """Start-of-segment-boundary hook (per outer loop iteration)."""

    def _pre_segment(self, mgr, dec, caches, tok, produced, plen, max_new,
                     active, dev, pending):
        """Hook before the segment launch.  Returns (caches, changed);
        ``changed`` means lanes were freed (the caller back-fills and
        re-runs the hook until it settles)."""
        return caches, False

    def _post_release(self, dec, caches, lanes):
        """Hook after lanes retire/evict (paged: scrub block tables so
        the released pages can never receive the lanes' dead writes)."""
        return caches

    def _result_tokens(self, state) -> list:
        return list(state.tokens)

    def _init_lanes(self, dec):
        """Lane-cache construction per run (paged: reuses the previous
        run's pools so the prefix cache keeps its contents)."""
        return dec.init_lanes()

    def _retain_caches(self, caches) -> None:
        """End-of-run hook: the paged engine stows the pools for the
        next run; the ring engine lets them be collected."""

    def run_lanes(self, source, on_finish, *, eos_id: Optional[int] = None,
                  cancel_check=None, now_fn=None) -> None:
        """Drive the lanes until ``source`` and all lanes drain.

        ``source(k)`` returns up to ``k`` work items (dicts with
        ``req_id``/``ids``/``max_new`` and optional ``tenant``/``meta``)
        in dispatch order — the server passes a closure over its policy
        queue so aging promotions are observed at every back-fill.
        ``on_finish(LaneState, result)`` fires as each request retires.
        ``cancel_check(LaneState) -> bool`` is polled at segment
        boundaries; a cancelled lane is evicted and reported with
        ``cancelled=True`` (§3.4 drain semantics, per lane).
        ``now_fn`` supplies admission/finish timestamps (defaults to
        wall clock; the server injects its virtual clock).
        """
        import jax.numpy as jnp
        now = now_fn if now_fn is not None else time.monotonic
        rec = self.recorder
        _ltrk = [f"replica{self.replica_id}/lane{i}"
                 for i in range(self.n_lanes)]
        mgr = self._new_manager()
        self.lane_manager = mgr
        self.dead_steps = 0
        self.drafted_total = 0
        self.accepted_total = 0
        dec = self._lane_decoder
        C = self.n_lanes
        caches = self._init_lanes(dec)
        # host-authoritative lane arrays; mirrored to device lazily (the
        # device copies persist across segments and are rebuilt only when
        # admission/eviction changes the lane composition — "dirty")
        tok = np.zeros(C, np.int32)
        produced = np.zeros(C, np.int32)
        plen = np.ones(C, np.int32)
        max_new = np.zeros(C, np.int32)
        active = np.zeros(C, bool)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)
        dev = {"d": None}               # (tok, produced, plen, max_new, act)
        pending: list = []              # popped but budget-blocked items
        # exposed for exception-safe callers: if a crash propagates out of
        # this method, items popped from the queue but not yet admitted to
        # a lane are recoverable via take_pending()
        self._pending_items = pending
        drained = {"source": False}

        def fill(backfill: bool = False) -> None:
            nonlocal caches
            free = mgr.free_lanes()
            # phase 1: claim admissible (item, lane) pairs under the
            # budget, in strict source order (a blocked head blocks all)
            claims = []
            while free:
                want = len(free) - len(pending)
                if want > 0 and not drained["source"]:
                    got = source(want)
                    if len(got) < want:
                        drained["source"] = True
                    pending.extend(got)
                if not pending:
                    break
                item = pending[0]
                ids = np.asarray(item["ids"], np.int64).reshape(-1)
                if not self._head_fits(mgr, item, ids):
                    # strict policy order: the head blocks, nothing bypasses
                    mgr.stats["blocked_on_budget"] += 1
                    break
                pending.pop(0)
                lane = free.pop(0)
                st = self._admit_item(mgr, lane, item, ids, now(), backfill)
                claims.append((st, lane, ids, item["max_new"]))
            if not claims:
                return
            # phase 2: prefill + lane insert (paged: page scatter / extend)
            caches = self._prefill_claims(mgr, dec, caches, claims, now,
                                          tok, plen, produced, max_new,
                                          active)
            if rec is not None:
                for st, lane, _, _ in claims:
                    rec.span("prefill", st.req_id, st.admit_t,
                             st.admit_t + max(st.ttft_s, 0.0),
                             track=_ltrk[lane])
            dev["d"] = None             # lane composition changed

        def finish(state, cancelled: bool, crashed: bool = False) -> None:
            t_fin = now()
            self.served += not cancelled
            if rec is not None:
                t0d = min(state.admit_t + max(state.ttft_s, 0.0), t_fin)
                rec.span("decode", state.req_id, t0d, t_fin,
                         track=_ltrk[state.lane])
            res = {
                "tokens": self._result_tokens(state), "cancelled": cancelled,
                "crashed": crashed,
                "ttft_s": state.ttft_s, "admit_t": state.admit_t,
                "finish_t": t_fin, "service_s": t_fin - state.admit_t,
                "lane": state.lane, "evictions": state.evictions}
            if self.speculative:
                res["drafted"] = state.drafted
                res["accepted"] = state.accepted
                res["accept_rate"] = state.accept_rate
            on_finish(state, res)

        inj = self.fault_injector
        fill()
        # `pending` in the condition: growth preemption (paged) can empty
        # every lane while the just-preempted head sits deferred for the
        # boundary — the next iteration lifts the deferral and re-admits
        # (an idle manager always admits its head, so this terminates)
        while active.any() or pending:
            self._boundary_reset()
            # segment boundary: collect client disconnects and injected
            # lane crashes, then evict + back-fill in one pass.  A
            # whole-engine crash (poll_segment) raises out of run_lanes;
            # the server requeues busy lanes + pending items.
            evictions = []                  # (lane, crashed)
            if cancel_check is not None:
                for lane in mgr.busy_lanes():
                    if cancel_check(mgr.lanes[lane]):
                        evictions.append((lane, False))
            if inj is not None:
                inj.poll_segment(self.replica_id)
                spec = inj.lane_crash_due(self.replica_id)
                while spec is not None:
                    taken = {lane for lane, _ in evictions}
                    busy = [ln for ln in mgr.busy_lanes()
                            if ln not in taken]
                    if not busy:
                        break
                    victim = spec.lane if spec.lane in busy else busy[0]
                    evictions.append((victim, True))
                    spec = inj.lane_crash_due(self.replica_id)
            if evictions:
                for lane, crashed in evictions:
                    st = mgr.evict(lane)
                    active[lane] = False
                    finish(st, cancelled=True, crashed=crashed)
                if dev["d"] is not None:
                    tok = np.array(dev["d"][0])       # refresh host mirror
                dev["d"] = None
                caches = self._post_release(
                    dec, caches, [lane for lane, _ in evictions])
                fill(backfill=True)
                if not active.any():
                    continue
            # paged: grow block tables for the coming segment, preempting
            # the youngest lanes on pool exhaustion; each preemption frees
            # a lane, so back-fill and re-settle until stable
            while True:
                caches, changed = self._pre_segment(
                    mgr, dec, caches, tok, produced, plen, max_new,
                    active, dev, pending)
                if not changed:
                    break
                fill(backfill=True)
            if not active.any():
                # every lane drained while the head sat deferred (it was
                # preempted in the same boundary the last lanes retired).
                # The deferral was lifted at the top of this iteration and
                # an idle manager admits its head, so this either admits
                # (progress) or pending is empty (the loop exits)
                fill(backfill=True)
                continue
            if dev["d"] is None:
                dev["d"] = (jnp.asarray(tok), jnp.asarray(produced),
                            jnp.asarray(plen), jnp.asarray(max_new),
                            jnp.asarray(active))
            tok_d, produced_d, plen_d, max_new_d, active_d = dev["d"]
            t_seg0 = now() if rec is not None else 0.0
            new_toks, tok_d, produced_d, caches, stopped, produced, dead = \
                dec.run_segment(self.params, caches, tok_d, produced_d,
                                plen_d, max_new_d, eos, active_d,
                                produced_before=produced)
            dev["d"] = (tok_d, produced_d, plen_d, max_new_d, active_d)
            self.dead_steps += dead
            self._accumulate_spec(mgr, dec)
            mgr.stats["dead_steps"] = self.dead_steps
            if rec is not None:
                t_seg1 = now()
                for lane in mgr.busy_lanes():
                    rec.span("decode_segment", mgr.lanes[lane].req_id,
                             t_seg0, t_seg1, track=_ltrk[lane])
            retired = False
            released = []
            for lane in mgr.busy_lanes():
                st = mgr.lanes[lane]
                st.tokens.extend(new_toks[lane])
                st.produced = int(produced[lane])
                if stopped[lane]:
                    st = mgr.retire(lane)
                    active[lane] = False
                    retired = True
                    released.append(lane)
                    finish(st, cancelled=False)
            if retired:
                # host tok mirror must be current before fill mutates it
                tok = np.array(tok_d)
                dev["d"] = None
                caches = self._post_release(dec, caches, released)
                fill(backfill=True)
        self._retain_caches(caches)


class PagedBatchedEngine(BatchedRealEngine):
    """Micro-batching over a block-paged KV pool with prefix reuse.

    Same lane loop, stop semantics and bitwise-token contract as
    :class:`BatchedRealEngine`, with the memory subsystem swapped
    (serving/paging.py):

    * **Admission charges actual footprint** — the prompt's pages, not
      the worst-case ring.  The same byte budget therefore admits more
      lanes when memory binds (the phantom-byte recovery the paging
      bench measures).
    * **Prefix reuse** — full prompt pages are content-addressed after
      prefill; a later prompt sharing the prefix re-acquires the cached
      pages and prefills only its suffix (extend prefill), cutting both
      memory and prefill compute.
    * **Page growth + preemption** — decode allocates pages as the
      sequence crosses page boundaries (one segment's worth ahead).  On
      exhaustion the youngest lane is preempted: its pages are freed and
      the request re-enters the pending list, resuming later via the
      PR-4 rule (re-prefill prompt + generated prefix), so its tokens
      stay bitwise-equal to an uninterrupted run.  The oldest lane is
      never preempted and the pool always holds one full sequence, so
      the loop cannot deadlock.

    The allocator persists across ``run_lanes`` calls — the prefix cache
    (LRU-parked pages) survives between drains, like a production
    server's; ``reset_transient`` drops only live references.
    """

    def __init__(self, cfg, params=None, replica_id: int = 0, seed: int = 0,
                 max_len: int = 256, segment_len: int = 16,
                 n_lanes: int = 4, budget_bytes: Optional[int] = None,
                 page_size: int = 16, draft_cfg=None, draft_params=None,
                 draft_k: int = 0, draft_seed: int = 0):
        import jax
        import jax.numpy as jnp
        from repro.serving.generate import (PagedLaneDecoder,
                                            SpeculativePagedLaneDecoder)
        from repro.serving.paging import BlockAllocator, pages_for
        super().__init__(cfg, params=params, replica_id=replica_id,
                         seed=seed, max_len=max_len, segment_len=segment_len,
                         n_lanes=n_lanes, budget_bytes=budget_bytes,
                         draft_cfg=draft_cfg, draft_params=draft_params,
                         draft_k=draft_k, draft_seed=draft_seed)
        if not self._bucketing:
            raise ValueError("block-paged KV needs a pure-attention stack "
                             f"(got pattern {cfg.block_pattern})")
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} not a multiple of "
                             f"page_size {page_size}")
        self.page_size = int(page_size)
        page_bytes = self.page_size * max(1, self._bytes_per_token)
        # a speculative lane's draft ring, denominated in target pages
        # (ceil): anonymous pages the admission layer charges per lane
        self._overhead_pages = -(-max_len * self._draft_bytes_per_token
                                 // page_bytes) if self.speculative else 0
        # same byte budget as the worst-case engine, denominated in pages
        # (floor); never below one full sequence (plus its draft
        # overhead) so a solo lane always fits
        self.n_pages = max(pages_for(max_len, self.page_size)
                           + self._overhead_pages,
                           self.budget_bytes // page_bytes)
        self.allocator = BlockAllocator(self.n_pages, self.page_size)
        if self.speculative:
            self._lane_decoder = SpeculativePagedLaneDecoder(
                self.lm, self.draft_lm, self.draft_params, max_len,
                self.n_lanes, segment_len, n_pages=self.n_pages + 1,
                page_size=self.page_size, draft_k=self.draft_k)
        else:
            self._lane_decoder = PagedLaneDecoder(
                self.lm, max_len, self.n_lanes, segment_len,
                n_pages=self.n_pages + 1, page_size=self.page_size)
        self._deferred: set = set()    # req_ids preempted at this boundary
        self._caches = None            # pools retained between runs
        # extend prefill: suffix tokens appended onto a gathered prefix
        # cache.  One jit; retraces per (suffix bucket, prefix extent).
        self._prefill_ext = jax.jit(
            lambda p, toks, pl, pcaches, fill_to: self.lm.prefill(
                p, {"tokens": toks}, prompt_len=pl, caches=pcaches,
                fill_to=fill_to))

    def engine_stats(self) -> dict:
        """Adds paged-pool page states (free/cached/held) and prefix-hit
        accounting to the batched stats."""
        st = super().engine_stats()
        st["pages"] = self.allocator.page_states()
        st["prefix_hits"] = self.allocator.stats["prefix_hits"]
        st["prefix_hit_pages"] = self.allocator.stats["prefix_hit_pages"]
        return st

    # ------------------------------------------------------------ lane hooks
    def _new_manager(self):
        from repro.serving.paging import PagedLaneManager
        self.allocator.reset_transient()   # drop refs leaked by a crash
        self._deferred = set()
        return PagedLaneManager(self.n_lanes, self.allocator,
                                self._bytes_per_token, self.max_len,
                                overhead_pages=self._overhead_pages)

    def _init_lanes(self, dec):
        # reuse the previous run's pools: the LRU-parked prefix pages
        # keep their KV, so cross-run prefix hits serve real contents.
        # If the pools are gone (first run, or the previous run crashed
        # before retaining them), the content cache must go with them.
        caches, self._caches = self._caches, None
        if caches is None:
            self.allocator.drop_cache()
            caches = dec.init_lanes()
        return caches

    def _retain_caches(self, caches) -> None:
        self._caches = caches

    def _boundary_reset(self) -> None:
        # a preempted request may be re-admitted at the NEXT boundary;
        # deferring it for the current one prevents admit/preempt churn
        self._deferred = set()

    def _head_fits(self, mgr, item, ids) -> bool:
        if item["req_id"] in self._deferred:
            return False
        # a preempted request re-admits on its FULL remaining footprint
        # (prefill + every growth page), not just the prefill pages: the
        # re-prefill is paid work, and admitting it into a pool that
        # cannot also hold its growth just preempts it again before it
        # produces a token — an admit/re-prefill/preempt cycle that burns
        # wall-clock without progress (the DES mirror makes the same
        # charge for resumed jobs)
        eff_len = len(ids)
        if item.get("_evictions", 0) > 0:
            eff_len += int(item["max_new"])
        return mgr.can_admit(eff_len, item["max_new"], ids=ids)

    def _admit_item(self, mgr, lane: int, item, ids, t_admit, backfill: bool):
        st = mgr.admit(lane, req_id=item["req_id"], prompt_len=len(ids),
                       max_new=item["max_new"],
                       tenant=item.get("tenant", "default"),
                       admit_t=t_admit, meta=item.get("meta"),
                       backfill=backfill, ids=ids)
        st.evictions = item.get("_evictions", 0)
        st.meta["_ids"] = ids
        st.meta["_resume_tokens"] = list(item.get("_resume_tokens", ()))
        return st

    def _result_tokens(self, state) -> list:
        return list(state.meta.get("_resume_tokens", ())) \
            + list(state.tokens)

    def _prefill_claims(self, mgr, dec, caches, claims, now, tok, plen,
                        produced, max_new, active):
        from repro.serving.generate import bucket_for
        from repro.serving.paging import pages_for
        ps = self.page_size
        P = self.max_len // ps
        cold = [c for c in claims if c[0].prefix_len == 0]
        warm = [c for c in claims if c[0].prefix_len > 0]
        # cold prompts: grouped full prefill (identical to the base
        # engine), then scatter the prompt pages into the pool
        groups: dict = {}
        for claim in cold:
            groups.setdefault(bucket_for(len(claim[2]), self.buckets),
                              []).append(claim)
        for group in groups.values():
            logits, pcache, plens = self._run_prefill_group(
                [ids for _, _, ids, _ in group], pad_rows=self.n_lanes)
            first = np.argmax(np.asarray(logits), axis=-1)
            k = len(group)
            bt_rows = np.zeros((k, P), np.int32)
            tgt = np.zeros((k, P), np.int32)   # pcache padded to max_len
            for r, (st, lane, ids, mx) in enumerate(group):
                bt_rows[r, :len(st.pages)] = st.pages
                npp = pages_for(len(ids), ps)
                tgt[r, :npp] = st.pages[:npp]
            caches = dec.insert_paged(
                caches, [lane for _, lane, _, _ in group], pcache,
                bt_rows, tgt)
            caches = self._insert_draft(
                dec, caches, [lane for _, lane, _, _ in group],
                [ids for _, _, ids, _ in group])
            self._post_insert(group, first, plens, now, tok, plen,
                              produced, max_new, active)
            for st, lane, ids, _ in group:
                mgr.register_prompt(lane, ids)
        # prefix hits: gather the cached pages, prefill only the suffix
        for claim in warm:
            caches = self._extend_prefill(mgr, dec, caches, claim, now,
                                          tok, plen, produced, max_new,
                                          active)
        return caches

    def _extend_prefill(self, mgr, dec, caches, claim, now, tok, plen,
                        produced, max_new, active):
        import jax.numpy as jnp
        from repro.serving.generate import bucket_for
        from repro.serving.paging import pages_for
        st, lane, ids, mx = claim
        ps = self.page_size
        P = self.max_len // ps
        n_match = st.prefix_len // ps
        Bf = bucket_for(len(ids), self.buckets)
        nf = -(-Bf // ps)
        pre_pages = np.zeros(nf, np.int32)
        pre_pages[:n_match] = st.pages[:n_match]
        pre_cache = dec.gather_prefix(caches, pre_pages, st.prefix_len)
        Ls = len(ids) - st.prefix_len
        Bs = min(bucket_for(Ls, self.buckets), nf * ps - st.prefix_len)
        toks = np.zeros((1, Bs), np.int32)
        toks[0, :Ls] = ids[st.prefix_len:]
        logits, pcache = self._prefill_ext(
            self.params, jnp.asarray(toks), jnp.asarray(Ls, jnp.int32),
            pre_cache, jnp.asarray(len(ids), jnp.int32))
        first = np.argmax(np.asarray(logits), axis=-1)
        npp = pages_for(len(ids), ps)
        bt_rows = np.zeros((1, P), np.int32)
        bt_rows[0, :len(st.pages)] = st.pages
        tgt = np.zeros((1, nf), np.int32)
        # only the NEW pages are scattered; the matched prefix already
        # lives in the pool (and may be shared — it must not be rewritten)
        tgt[0, n_match:npp] = st.pages[n_match:npp]
        caches = dec.insert_paged(caches, [lane], pcache, bt_rows, tgt)
        # prefix hits still do a FULL draft prefill: the draft side has
        # no prefix cache (and cannot corrupt tokens, only acceptance)
        caches = self._insert_draft(dec, caches, [lane], [ids])
        self._post_insert([claim], first, [len(ids)], now, tok, plen,
                          produced, max_new, active)
        mgr.register_prompt(lane, ids)
        return caches

    def _post_release(self, dec, caches, lanes):
        # scrub the released lanes' block tables: their dead writes (the
        # lane keeps stepping while inactive) must land on the trash
        # page, never on a page the allocator may hand to someone else
        P = self.max_len // self.page_size
        rows = np.zeros((len(lanes), P), np.int32)
        return dec.set_bt(caches, list(lanes), rows)

    def _pre_segment(self, mgr, dec, caches, tok, produced, plen, max_new,
                     active, dev, pending):
        from repro.serving.paging import pages_for
        ps = self.page_size
        P = self.max_len // ps
        # speculative segments write verify positions ahead of the fill
        # level (rounds x (K+1) slots); an unallocated page would silently
        # route those writes to the trash page and lose real KV
        K = self._growth_span
        changed = False
        new_rows: dict = {}                   # lane -> block-table row
        order = sorted(mgr.busy_lanes(),
                       key=lambda ln: mgr.lanes[ln].meta["_admit_seq"])
        for lane in order:
            st = mgr.lanes[lane]
            if st is None:                    # preempted earlier this pass
                continue
            # pages for every slot the coming segment can write:
            # the next write lands at plen + produced - 1
            target = min(self.max_len,
                         int(plen[lane]) + int(produced[lane]) + K - 1)
            need = pages_for(target, ps)
            if need <= len(st.pages):
                continue
            while not mgr.grow(lane, need):
                seq = st.meta["_admit_seq"]
                younger = [l for l in mgr.busy_lanes()
                           if mgr.lanes[l].meta["_admit_seq"] > seq]
                victim = max(younger, key=lambda l:
                             mgr.lanes[l].meta["_admit_seq"]) \
                    if younger else lane
                self._preempt_lane(mgr, victim, tok, produced, active,
                                   dev, pending)
                new_rows[victim] = np.zeros(P, np.int32)
                changed = True
                if victim == lane:
                    break
            if mgr.lanes[lane] is st:         # grown (not self-preempted)
                row = np.zeros(P, np.int32)
                row[:len(st.pages)] = st.pages
                new_rows[lane] = row
        if new_rows:
            idx = sorted(new_rows)
            caches = dec.set_bt(caches, idx,
                                np.stack([new_rows[i] for i in idx]))
        return caches, changed

    def _preempt_lane(self, mgr, lane, tok, produced, active, dev,
                      pending) -> None:
        """Free a lane's pages mid-flight and requeue the request at the
        head of the pending list (it was admitted earliest).  The resume
        item re-prefills prompt + generated prefix — the PR-4 rule — so
        the final token sequence matches an uninterrupted run."""
        if dev["d"] is not None:
            tok[:] = np.array(dev["d"][0])    # refresh host mirrors
            produced[:] = np.array(dev["d"][1])
            dev["d"] = None
        st = mgr.preempt(lane)
        active[lane] = False
        meta = {k: v for k, v in st.meta.items()
                if k not in ("_admit_seq", "_ids", "_resume_tokens")}
        item = {
            "req_id": st.req_id,
            "ids": np.concatenate([
                np.asarray(st.meta["_ids"], np.int64).reshape(-1),
                np.asarray(st.tokens, np.int64)]),
            "max_new": st.max_new - len(st.tokens),
            "tenant": st.tenant, "meta": meta,
            "_evictions": st.evictions,
            "_resume_tokens": st.meta["_resume_tokens"] + list(st.tokens),
        }
        pending.insert(0, item)
        self._deferred.add(st.req_id)
