"""Replica engine: the serial backend behind the admission layer.

Two execution modes share one interface:

* ``RealEngine`` — jitted prefill + greedy decode of an actual LM (used by
  the examples and integration tests with reduced configs on CPU; on TPU the
  same class serves full configs with the Pallas decode kernels swapped in
  via kernels/ops.py);
* ``SimEngine`` — virtual-clock engine using a ServiceTimeModel (used by the
  queueing benchmarks, where thousands of requests are served).

Both are strictly serial: one request in flight per replica — the regime the
paper targets (§2.3).  Disconnect semantics per §3.4: cancellation while
queued removes the heap entry (lazy); cancellation mid-generation drains the
response to free the dispatch slot.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.serving.service_time import ServiceTimeModel


class SimEngine:
    """Virtual-time serial backend."""

    def __init__(self, model: ServiceTimeModel, replica_id: int = 0):
        self.model = model
        self.replica_id = replica_id
        self.busy_until = 0.0
        self.served = 0

    def execute(self, start: float, prompt_tokens: int,
                output_tokens: int) -> tuple[float, float]:
        """Returns (ttft_s, service_s); advances the virtual clock."""
        service = self.model.service(prompt_tokens, output_tokens)
        ttft = self.model.overhead_s + prompt_tokens / self.model.prefill_tok_per_s
        self.busy_until = start + service
        self.served += 1
        return ttft, service


class RealEngine:
    """Actual LM decode on device (reduced configs on this CPU container)."""

    def __init__(self, cfg, params=None, replica_id: int = 0, seed: int = 0,
                 max_len: int = 256):
        import jax
        import jax.numpy as jnp
        from repro.models.model import LM

        self.cfg = cfg
        self.lm = LM(cfg)
        self.replica_id = replica_id
        self.max_len = max_len
        self.params = params if params is not None \
            else self.lm.init(jax.random.key(seed))
        self.busy_until = 0.0
        self.served = 0

        self._prefill = jax.jit(lambda p, b: self.lm.prefill(p, b,
                                                             pad_to=max_len))
        self._decode = jax.jit(self.lm.decode_step)

    def generate(self, prompt_ids: np.ndarray, max_new_tokens: int = 32,
                 eos_id: Optional[int] = None) -> dict:
        """Greedy decode.  prompt_ids: (S,) ints.  Returns timing + tokens."""
        import jax.numpy as jnp
        t0 = time.monotonic()
        batch = {"tokens": jnp.asarray(prompt_ids, jnp.int32)[None]}
        logits, caches = self._prefill(self.params, batch)
        tok = int(np.argmax(np.asarray(logits)[0]))
        ttft = time.monotonic() - t0
        out = [tok]
        for _ in range(max_new_tokens - 1):
            if eos_id is not None and tok == eos_id:
                break
            if len(prompt_ids) + len(out) >= self.max_len:
                break
            logits, caches = self._decode(
                self.params, caches, {"tokens": jnp.full((1, 1), tok, jnp.int32)})
            tok = int(np.argmax(np.asarray(logits)[0]))
            out.append(tok)
        self.served += 1
        return {"tokens": out, "ttft_s": ttft,
                "service_s": time.monotonic() - t0}
