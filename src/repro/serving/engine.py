"""Replica engine: the serial backend behind the admission layer.

Two execution modes share one interface:

* ``RealEngine`` — jitted prefill + fused on-device greedy decode of an
  actual LM (used by the examples, the serve benchmark and integration tests
  with reduced configs on CPU; on TPU the same class serves full configs
  with the Pallas decode kernels swapped in via kernels/ops.py);
* ``SimEngine`` — virtual-clock engine using a ServiceTimeModel (used by the
  queueing benchmarks, where thousands of requests are served).

Both are strictly serial: one request in flight per replica — the regime the
paper targets (§2.3).  Disconnect semantics per §3.4: cancellation while
queued removes the heap entry (lazy); cancellation mid-generation stops the
fused loop at the next segment boundary (``request_cancel``), draining the
response to free the dispatch slot within ``segment_len`` tokens.

``RealEngine`` generation path (PR 3):

* **Bucketed prefill** — prompts are right-padded to a small geometric set
  of lengths (powers of two up to ``max_len``; see
  ``generate.geometric_buckets``), so a mixed-length admission stream
  triggers O(log max_len) jit compiles instead of one per distinct prompt
  length.  The true ``prompt_len`` rides into the jitted prefill as a
  dynamic scalar: logits are gathered at ``prompt_len - 1`` and the cache
  fill level is reset to ``prompt_len`` (models/model.py).  Padded prefill
  is only bit-safe for causal-local stacks, so bucketing engages when the
  block pattern is pure attention and falls back to exact lengths (the seed
  behavior) otherwise.
* **Ring-buffer KV cache** — caches hold ``max_len`` slots; decode writes
  step ``t`` at slot ``t % max_len`` (models/attention.py), so capacity is
  an attention-window bound, never a per-request reallocation.
* **Fused decode** — ``generate`` drives ``serving.generate.FusedDecoder``:
  segments of ``segment_len`` tokens run in one jitted ``lax.while_loop``
  with the EOS/length stop on device and the caches donated in place; the
  host syncs once per segment.  The seed per-token Python loop is retained
  as ``generate_reference`` — the bitwise token-sequence equivalence oracle
  (tests/test_generate.py), matching the PR 1/PR 2 oracle pattern.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.serving.service_time import ServiceTimeModel


class SimEngine:
    """Virtual-time serial backend."""

    def __init__(self, model: ServiceTimeModel, replica_id: int = 0):
        self.model = model
        self.replica_id = replica_id
        self.busy_until = 0.0
        self.served = 0

    def execute(self, start: float, prompt_tokens: int,
                output_tokens: int) -> tuple[float, float]:
        """Returns (ttft_s, service_s); advances the virtual clock."""
        service = self.model.service(prompt_tokens, output_tokens)
        ttft = self.model.overhead_s + prompt_tokens / self.model.prefill_tok_per_s
        self.busy_until = start + service
        self.served += 1
        return ttft, service


# Padded (bucketed) prefill is only used when every block's per-position
# state is causal-local; SSM/xLSTM recurrences fold pad tokens into their
# state and MoE capacity routing lets pad tokens evict real ones.
_BUCKET_SAFE_KINDS = ("attn",)


class RealEngine:
    """Actual LM decode on device (reduced configs on this CPU container)."""

    def __init__(self, cfg, params=None, replica_id: int = 0, seed: int = 0,
                 max_len: int = 256, segment_len: int = 16):
        import jax
        import jax.numpy as jnp
        from repro.models.model import LM
        from repro.serving.generate import FusedDecoder, geometric_buckets

        self.cfg = cfg
        self.lm = LM(cfg)
        self.replica_id = replica_id
        self.max_len = max_len
        self.segment_len = segment_len
        self.params = params if params is not None \
            else self.lm.init(jax.random.key(seed))
        self.busy_until = 0.0
        self.served = 0
        self._cancel = False

        self._bucketing = all(k in _BUCKET_SAFE_KINDS
                              for k in cfg.block_pattern)
        self.buckets = geometric_buckets(max_len) if self._bucketing else ()
        # One jit; retraces once per bucket shape (prompt_len is dynamic).
        self._prefill = jax.jit(
            lambda p, toks, plen: self.lm.prefill(
                p, {"tokens": toks}, pad_to=max_len, prompt_len=plen))
        self._decode = jax.jit(self.lm.decode_step)       # oracle path
        self._decoders = {segment_len: FusedDecoder(self.lm, max_len,
                                                    segment_len)}

    # ---------------------------------------------------------------- admin
    def request_cancel(self) -> None:
        """§3.4 mid-generation disconnect: the fused loop observes this flag
        at the next segment boundary and drains."""
        self._cancel = True

    def _decoder(self, segment_len: int):
        dec = self._decoders.get(segment_len)
        if dec is None:
            from repro.serving.generate import FusedDecoder
            dec = FusedDecoder(self.lm, self.max_len, segment_len)
            self._decoders[segment_len] = dec
        return dec

    # -------------------------------------------------------------- prefill
    def _run_prefill(self, prompt_ids: np.ndarray):
        """Bucket-pad + prefill.  Returns (last_logits, caches, prompt_len)."""
        import jax.numpy as jnp
        from repro.serving.generate import bucket_for
        ids = np.asarray(prompt_ids, np.int32).reshape(-1)
        plen = len(ids)
        if plen < 1:
            raise ValueError("empty prompt: prefill needs >= 1 token "
                             "(dynamic_slice would silently clamp to 0)")
        if self._bucketing:
            bucket = bucket_for(plen, self.buckets)
        else:
            bucket = plen                     # exact length (seed behavior)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = ids
        logits, caches = self._prefill(self.params, jnp.asarray(toks),
                                       jnp.asarray(plen, jnp.int32))
        return logits, caches, plen

    # ------------------------------------------------------------- generate
    def generate(self, prompt_ids: np.ndarray, max_new_tokens: int = 32,
                 eos_id: Optional[int] = None, cancel_cb=None,
                 segment_len: Optional[int] = None) -> dict:
        """Fused greedy decode.  prompt_ids: (S,) ints.

        Returns {"tokens", "ttft_s", "service_s", "cancelled", "segments"}.
        ``cancel_cb`` (optional nullary) is polled with the engine's own
        cancel flag between scan segments.
        """
        self._cancel = False
        t0 = time.monotonic()
        logits, caches, plen = self._run_prefill(prompt_ids)
        tok = int(np.argmax(np.asarray(logits)[0]))
        ttft = time.monotonic() - t0

        def cancelled():
            return self._cancel or (cancel_cb is not None and cancel_cb())

        dec = self._decoder(segment_len or self.segment_len)
        out = dec.decode(self.params, caches, tok, plen, max_new_tokens,
                         eos_id=eos_id, cancel_check=cancelled)
        self.served += 1
        self._cancel = False
        return {"tokens": out["tokens"], "ttft_s": ttft,
                "service_s": time.monotonic() - t0,
                "cancelled": out["cancelled"], "segments": out["segments"]}

    def generate_reference(self, prompt_ids: np.ndarray,
                           max_new_tokens: int = 32,
                           eos_id: Optional[int] = None) -> dict:
        """Seed per-token Python loop (one host sync + dispatch per token).

        Kept in-tree as the equivalence oracle for the fused loop: same
        prefill, same stop-condition order, so token sequences must match
        bitwise (tests/test_generate.py).
        """
        import jax.numpy as jnp
        t0 = time.monotonic()
        logits, caches, plen = self._run_prefill(prompt_ids)
        tok = int(np.argmax(np.asarray(logits)[0]))
        ttft = time.monotonic() - t0
        out = [tok]
        for _ in range(max_new_tokens - 1):
            if eos_id is not None and tok == eos_id:
                break
            if plen + len(out) >= self.max_len:
                break
            logits, caches = self._decode(
                self.params, caches, {"tokens": jnp.full((1, 1), tok, jnp.int32)})
            tok = int(np.argmax(np.asarray(logits)[0]))
            out.append(tok)
        self.served += 1
        return {"tokens": out, "ttft_s": ttft,
                "service_s": time.monotonic() - t0}
