"""Service-time models: roofline-calibrated and paper-measured.

The CPU container cannot time TPU generation, so end-to-end queueing results
use a cost model.  Two calibrations:

* ``from_arch`` — derived from this framework's own roofline terms: prefill
  is compute-bound (2*N_active FLOPs/token at ``mfu``), decode is
  memory-bound (active params + KV bytes per token at ``hbm_frac`` of HBM
  bandwidth).  This is the TPU-serving analogue of the paper's M1/4090
  measurements.
* ``paper_*`` — the paper's measured distributions (Table 1 M1 service
  stats; §5.5 RTX 4090 N(3.5,0.8)/N(8.9,2.0)), for faithful replication of
  its queueing results.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.simulation import ServiceDist
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


@dataclass
class ServiceTimeModel:
    """service(prompt_tokens, output_tokens) in seconds."""
    prefill_tok_per_s: float
    decode_tok_per_s: float
    overhead_s: float = 0.010

    def service(self, prompt_tokens: int, output_tokens: int) -> float:
        return (self.overhead_s
                + prompt_tokens / self.prefill_tok_per_s
                + output_tokens / self.decode_tok_per_s)

    @classmethod
    def from_arch(cls, cfg, chips: int = 1, mfu: float = 0.4,
                  hbm_frac: float = 0.7, kv_tokens: int = 2048
                  ) -> "ServiceTimeModel":
        n_active = cfg.active_param_count()
        prefill = chips * PEAK_FLOPS * mfu / (2.0 * n_active)
        kv_bytes_per_tok = (2 * cfg.kv_dim * 2
                            * sum(k.startswith("attn") for k in cfg.block_pattern)
                            * cfg.pattern_repeats)
        bytes_per_decode = 2.0 * n_active + kv_bytes_per_tok * kv_tokens
        decode = chips * HBM_BW * hbm_frac / bytes_per_decode
        return cls(prefill_tok_per_s=prefill, decode_tok_per_s=decode)


# --- the paper's measured calibrations -------------------------------------

# RTX 4090 + Gemma3:4b steady-state DES calibration (§5.5)
PAPER_4090_SHORT = ServiceDist(mean=3.5, std=0.8)
PAPER_4090_LONG = ServiceDist(mean=8.9, std=2.0)

# Apple M1 + Gemma3:4b sequential service times (Table 1)
PAPER_M1_SHORT = ServiceDist(mean=2.1, std=1.1)
PAPER_M1_LONG = ServiceDist(mean=29.7, std=11.7)


def sample_output_tokens(rng, klass: str) -> int:
    """Response-length draw consistent with the corpus class boundaries."""
    if klass == "short":
        return int(np.clip(rng.lognormal(3.7, 0.8), 1, 199))
    if klass == "medium":
        return int(rng.integers(200, 800))
    return int(np.clip(rng.lognormal(np.log(1400.0), 0.45), 800, 8000))
