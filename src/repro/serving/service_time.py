"""Service-time models: roofline-calibrated and paper-measured.

The CPU container cannot time TPU generation, so end-to-end queueing results
use a cost model.  Two calibrations:

* ``from_arch`` — derived from this framework's own roofline terms: prefill
  is compute-bound (2*N_active FLOPs/token at ``mfu``), decode is
  memory-bound (active params + KV bytes per token at ``hbm_frac`` of HBM
  bandwidth).  This is the TPU-serving analogue of the paper's M1/4090
  measurements.
* ``paper_*`` — the paper's measured distributions (Table 1 M1 service
  stats; §5.5 RTX 4090 N(3.5,0.8)/N(8.9,2.0)), for faithful replication of
  its queueing results.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.simulation import ServiceDist
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def expected_speedup(accept_rate, draft_k: int, draft_cost: float = 0.15):
    """Expected decode speedup of draft-verify speculation.

    With per-position acceptance rate ``a`` and draft depth ``k``, one
    round emits ``E = (1 - a^(k+1)) / (1 - a)`` target tokens (the
    accepted prefix plus the bonus token) and costs ``k`` draft forwards
    plus one verify forward, so the speedup over serial decode is
    ``E / (k * draft_cost + 1)`` where ``draft_cost`` is the draft/target
    per-forward cost ratio.  Can be < 1 at low acceptance — speculation
    is not free.  Accepts scalars or arrays; ``draft_k == 0`` is exactly
    1.0 (no speculation).
    """
    if draft_k == 0:
        a = np.asarray(accept_rate, np.float64)
        return np.ones_like(a) if a.ndim else 1.0
    a = np.clip(np.asarray(accept_rate, np.float64), 0.0, 1.0 - 1e-9)
    tokens_per_round = (1.0 - a ** (draft_k + 1)) / (1.0 - a)
    out = tokens_per_round / (draft_k * draft_cost + 1.0)
    return out if out.ndim else float(out)


@dataclass
class ServiceTimeModel:
    """service(prompt_tokens, output_tokens) in seconds.

    ``effective_rate`` is the speculative-decoding seam: a multiplier on
    the decode rate (``expected_speedup(accept_rate, k)`` when a draft
    lane is live, 1.0 otherwise).  The default of 1.0 is an IEEE-exact
    identity — ``x * 1.0 == x`` — so every pre-speculation calibration
    and BENCH grid is bitwise unchanged.
    """
    prefill_tok_per_s: float
    decode_tok_per_s: float
    overhead_s: float = 0.010
    effective_rate: float = 1.0

    def service(self, prompt_tokens: int, output_tokens: int) -> float:
        return (self.overhead_s
                + prompt_tokens / self.prefill_tok_per_s
                + output_tokens
                / (self.decode_tok_per_s * self.effective_rate))

    def service_batch(self, prompt_tokens, output_tokens) -> np.ndarray:
        """Vectorized ``service`` over whole request batches (float64) —
        what the SoA simulation path (core.sim_fast) consumes."""
        return (self.overhead_s
                + np.asarray(prompt_tokens, np.float64)
                / self.prefill_tok_per_s
                + np.asarray(output_tokens, np.float64)
                / (self.decode_tok_per_s * self.effective_rate))

    @classmethod
    def from_arch(cls, cfg, chips: int = 1, mfu: float = 0.4,
                  hbm_frac: float = 0.7, kv_tokens: int = 2048
                  ) -> "ServiceTimeModel":
        n_active = cfg.active_param_count()
        prefill = chips * PEAK_FLOPS * mfu / (2.0 * n_active)
        kv_bytes_per_tok = (2 * cfg.kv_dim * 2
                            * sum(k.startswith("attn") for k in cfg.block_pattern)
                            * cfg.pattern_repeats)
        bytes_per_decode = 2.0 * n_active + kv_bytes_per_tok * kv_tokens
        decode = chips * HBM_BW * hbm_frac / bytes_per_decode
        return cls(prefill_tok_per_s=prefill, decode_tok_per_s=decode)


# --- the paper's measured calibrations -------------------------------------

# RTX 4090 + Gemma3:4b steady-state DES calibration (§5.5)
PAPER_4090_SHORT = ServiceDist(mean=3.5, std=0.8)
PAPER_4090_LONG = ServiceDist(mean=8.9, std=2.0)

# Apple M1 + Gemma3:4b sequential service times (Table 1)
PAPER_M1_SHORT = ServiceDist(mean=2.1, std=1.1)
PAPER_M1_LONG = ServiceDist(mean=29.7, std=11.7)


# per-class response-length draws: (lognormal mu, sigma, clip lo, clip hi);
# medium is a uniform integer range instead
_LEN_SHORT = (3.7, 0.8, 1, 199)
_LEN_LONG = (float(np.log(1400.0)), 0.45, 800, 8000)
_LEN_MEDIUM = (200, 800)


def sample_output_tokens(rng, klass: str) -> int:
    """Response-length draw consistent with the corpus class boundaries."""
    if klass == "medium":
        return int(rng.integers(*_LEN_MEDIUM))
    mu, sig, lo, hi = _LEN_SHORT if klass == "short" else _LEN_LONG
    return int(np.clip(rng.lognormal(mu, sig), lo, hi))


def sample_output_tokens_batch(rng, klasses) -> np.ndarray:
    """Vectorized :func:`sample_output_tokens` over an array of class
    names (or ``sim_fast.KLASSES`` codes) — one draw pass per class."""
    klasses = np.asarray(klasses)
    if klasses.dtype.kind in "US":
        from repro.core.sim_fast import KLASSES
        code = {k: i for i, k in enumerate(KLASSES)}
        klasses = np.array([code[k] for k in klasses], np.int8)
    n = klasses.shape[0]
    out = np.empty(n, np.int64)
    short = klasses == 1
    med = klasses == 2
    long = ~(short | med)
    for mask, (mu, sig, lo, hi) in ((short, _LEN_SHORT), (long, _LEN_LONG)):
        out[mask] = np.clip(rng.lognormal(mu, sig, int(mask.sum())),
                            lo, hi).astype(np.int64)
    out[med] = rng.integers(*_LEN_MEDIUM, size=int(med.sum()))
    return out
