"""Fused on-device greedy generation: segmented ``lax.while_loop`` decode.

The seed decode loop (kept as ``RealEngine.generate_reference``) runs one
jitted ``decode_step`` per token and syncs to host every step — ``np.argmax``
on the logits plus a re-upload of the sampled token — so per-token cost on
small models is dispatch latency, not compute.  :class:`FusedDecoder`
replaces it with a *segmented* device loop:

* one jitted call runs up to ``segment_len`` decode steps in a
  ``lax.while_loop`` whose carry holds the current token, the KV caches and
  the emitted-token buffer — tokens never leave the device inside a segment;
* the EOS / ``max_len`` / ``max_new`` stop condition is evaluated on device
  in the loop predicate, mirroring the oracle's Python ``break``s exactly
  (same check order, so token sequences are bitwise-comparable);
* the KV caches are **donated** into the segment call
  (``donate_argnums``), so on backends with donation support the ring
  buffers update in place instead of being copied once per call;
* the host syncs once per segment to read the emitted tokens and check the
  engine's cancel flag (§3.4 drain semantics: a disconnect observed between
  segments stops generation at the segment boundary, freeing the serial
  dispatch slot within ``segment_len`` tokens).

Dispatch overhead is therefore amortized to ``1/segment_len`` of the seed
loop's; ``benchmarks/serve_bench.py`` measures the ratio and writes it to
``BENCH_serve.json``.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Older CPU jaxlibs ignore donation with a warning; the fused loop is still
# correct (the copy just reappears).  Suppressed around the segment call
# only — not globally — so applications keep the signal for their own jits.
_DONATION_WARNING = "Some donated buffers were not usable"


class FusedDecoder:
    """Device-resident segmented greedy decoder for one ``LM``.

    One instance per (model, max_len, segment_len); the segment function is
    compiled once per cache shape (i.e. per cache capacity x batch size).
    """

    def __init__(self, lm, max_len: int, segment_len: int = 16):
        assert segment_len >= 1
        self.lm = lm
        self.max_len = max_len
        self.segment_len = segment_len
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    def _segment_impl(self, params, caches, tok, produced, prompt_len,
                      max_new, eos):
        """Run up to ``segment_len`` decode steps on device.

        tok: () int32 last emitted token; produced: () int32 tokens emitted
        so far (including the prefill token); eos: () int32 (-1 = disabled).
        Returns (buf (K,) int32 with -1 padding, tok, produced, caches,
        stopped) — ``stopped`` True when the generation-level stop condition
        holds, i.e. the host should not launch another segment.
        """
        K = self.segment_len
        max_len = self.max_len
        buf0 = jnp.full((K,), -1, jnp.int32)

        def live(tok, produced):
            # The oracle's break conditions, in order: EOS, cache/window
            # budget, request budget.
            return ((tok != eos)
                    & (prompt_len + produced < max_len)
                    & (produced < max_new))

        def cond(c):
            i, tok, produced, _, _ = c
            return (i < K) & live(tok, produced)

        def body(c):
            i, tok, produced, caches, buf = c
            logits, caches = self.lm.decode_step(
                params, caches, {"tokens": tok.reshape(1, 1)})
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            buf = jax.lax.dynamic_update_slice(buf, tok[None], (i,))
            return i + 1, tok, produced + 1, caches, buf

        _, tok, produced, caches, buf = jax.lax.while_loop(
            cond, body,
            (jnp.zeros((), jnp.int32), tok, produced, caches, buf0))
        return buf, tok, produced, caches, ~live(tok, produced)

    def decode(self, params, caches, first_token: int, prompt_len: int,
               max_new_tokens: int, eos_id: Optional[int] = None,
               cancel_check=None, on_segment=None) -> dict:
        """Greedy-decode from a prefilled cache.

        ``first_token`` is the prefill argmax (already emitted).  Returns
        {"tokens": [first_token, ...], "cancelled": bool, "segments": int,
        "caches": final cache pytree}.

        ``on_segment(new_tokens)`` fires at every host sync with the
        tokens emitted since the previous call — the prefill token before
        the first segment, then one call per segment.  This is the SSE
        streaming hook: segment boundaries are the only points where
        tokens reach the host, so they are the natural flush granularity
        for the sidecar (and the same join points where cancellation and
        injected crashes land).  Exceptions from the callback propagate —
        emission is part of serving the request.
        """
        out = [int(first_token)]
        if on_segment is not None:
            on_segment([int(first_token)])
        tok = jnp.asarray(first_token, jnp.int32)
        produced = jnp.asarray(1, jnp.int32)
        plen = jnp.asarray(prompt_len, jnp.int32)
        max_new = jnp.asarray(max_new_tokens, jnp.int32)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)
        cancelled = False
        segments = 0
        # The first segment's predicate replays the oracle's post-prefill
        # checks, so a request that is already complete runs zero steps.
        while True:
            if cancel_check is not None and cancel_check():
                cancelled = True
                break
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=_DONATION_WARNING)
                buf, tok, produced, caches, stopped = self._segment(
                    params, caches, tok, produced, plen, max_new, eos)
            segments += 1
            n_new = int(produced) - len(out)     # one host sync per segment
            buf_np = np.asarray(buf)
            new = [int(x) for x in buf_np[:n_new]]
            out.extend(new)
            if on_segment is not None and new:
                on_segment(new)
            if bool(stopped):
                break
        return {"tokens": out, "cancelled": cancelled, "segments": segments,
                "caches": caches}


class SpeculativeDecoder:
    """Serial draft-verify greedy decoder (speculative decoding, B=1).

    Each *round* runs the small draft model ``draft_k`` steps to propose a
    token chain, then scores the pending token plus the whole chain with
    ONE multi-position target forward (``LM.verify_step``) and accepts the
    longest prefix of drafts that match the target's own greedy argmaxes.
    Because every emitted token is a target argmax conditioned on the
    accepted prefix, the token sequence is **bitwise-equal** to the
    non-speculative fused/serial greedy reference — speculation changes
    how many target dispatches the sequence costs, never its contents.

    Round semantics (greedy accept-longest-prefix + bonus token):

    * verify feeds ``[pending, d_1..d_K]`` at fill levels ``t..t+K`` and
      takes target argmaxes ``a_0..a_K``;
    * ``m`` = longest prefix with ``d_i == a_{i-1}``; the round emits
      ``a_0..a_min(m, caps)`` (so a full match emits K+1 tokens — the
      K accepted drafts' successors plus the *bonus* ``a_K``), truncated
      by the serial stop rules (EOS inside the block, ring capacity,
      request budget) in exactly the oracle's check order;
    * commit advances both caches' fill levels to the accepted extent —
      rejected drafts roll back by simply **not advancing** ``t`` (stale
      KV past the fill level is masked and overwritten in write order
      later), so rollback costs no recompilation and no cleanup pass;
    * when the round fully accepts, the draft cache is one token short
      (it never consumed ``d_K``) — the next round's *catch-up step*
      feeds that tail token first.  Lanes without a tail dummy-feed: the
      write at the frozen slot is overwritten by the next real write and
      step-0 logits are never used.

    A live round always emits >= 1 token (``a_0`` costs the same target
    dispatch a serial step would), so all-rejected rounds still progress.

    Requires a pure-attention stack (the verify forward is an attention-
    cache operation) and a shared vocabulary between draft and target.
    """

    def __init__(self, lm, draft_lm, max_len: int, draft_k: int):
        if draft_k < 1:
            raise ValueError("draft_k must be >= 1 (K=0 is the fused path)")
        if lm.cfg.vocab_size != draft_lm.cfg.vocab_size:
            raise ValueError(
                f"draft/target vocab mismatch: {draft_lm.cfg.vocab_size} "
                f"vs {lm.cfg.vocab_size}")
        self.lm = lm
        self.draft_lm = draft_lm
        self.max_len = max_len
        self.draft_k = int(draft_k)
        self._round = jax.jit(self._round_impl, donate_argnums=(2, 3))

    def _round_impl(self, params, draft_params, caches, dcaches, tok,
                    produced, has_tail, tail, plen, max_new, eos):
        """One draft-verify-commit round, fully on device.

        Scalar carries: ``tok`` pending token, ``produced`` emitted count,
        ``has_tail``/``tail`` the draft catch-up state.  Returns
        (emit (K+1,) -1-padded, n_emit, tok, produced, has_tail, tail,
        caches, dcaches, stopped).
        """
        K = self.draft_k
        # --- draft phase: catch-up step + K chain steps -----------------
        d0 = [c["t"] for c in dcaches]
        feed0 = jnp.where(has_tail, tail, tok)
        _, dcaches = self.draft_lm.decode_step(
            draft_params, dcaches, {"tokens": feed0.reshape(1, 1)})
        ht = has_tail.astype(jnp.int32)
        dcaches = tuple({**c, "t": t0 + ht} for c, t0 in zip(dcaches, d0))

        def dstep(carry, _):
            cur, dc = carry
            lg, dc = self.draft_lm.decode_step(
                draft_params, dc, {"tokens": cur.reshape(1, 1)})
            nxt = jnp.argmax(lg[0]).astype(jnp.int32)
            return (nxt, dc), nxt

        (_, dcaches), d = jax.lax.scan(dstep, (tok, dcaches), None, length=K)

        # --- verify: one multi-position target forward ------------------
        feed = jnp.concatenate([tok[None], d])             # (K+1,)
        base_t = caches[0]["t"][0]                         # pre-round fill
        vlog, caches = self.lm.verify_step(params, caches,
                                           {"tokens": feed[None]})
        a = jnp.argmax(vlog[0], axis=-1).astype(jnp.int32)  # (K+1,)

        # --- acceptance: longest matching prefix + oracle stop order ----
        ok = (d == a[:K]).astype(jnp.int32)
        m_chain = jnp.cumprod(ok).sum()
        cap = jnp.minimum(m_chain + 1,
                          jnp.minimum(self.max_len - plen - produced,
                                      max_new - produced))
        idx = jnp.arange(K + 1, dtype=jnp.int32)
        is_eos = (a == eos) & (idx < cap)
        n_emit = jnp.where(is_eos.any(),
                           jnp.argmax(is_eos).astype(jnp.int32) + 1, cap)

        # --- commit ------------------------------------------------------
        emit = jnp.where(idx < n_emit, a, -1)
        new_tok = a[n_emit - 1]
        produced = produced + n_emit
        caches = tuple({**c, "t": jnp.full_like(c["t"], base_t + n_emit)}
                       for c in caches)
        n_keep = jnp.minimum(n_emit, K)
        dcaches = tuple({**c, "t": jnp.full_like(c["t"], base_t + n_keep)}
                        for c in dcaches)
        full = n_emit == K + 1
        stopped = ~((new_tok != eos)
                    & (plen + produced < self.max_len)
                    & (produced < max_new))
        return (emit, n_emit, new_tok, produced, full, d[K - 1], caches,
                dcaches, stopped)

    def decode(self, params, draft_params, caches, dcaches,
               first_token: int, prompt_len: int, max_new_tokens: int,
               eos_id: Optional[int] = None, cancel_check=None,
               on_segment=None) -> dict:
        """Greedy-decode from prefilled target + draft caches.

        Mirrors :meth:`FusedDecoder.decode` (same result keys, same
        cancel/stream join points — here every round is a segment), plus
        ``drafted``/``accepted`` counters (``accepted / drafted`` is the
        observed acceptance rate the admission layer feeds back into its
        effective-service-time key).
        """
        K = self.draft_k
        out = [int(first_token)]
        if on_segment is not None:
            on_segment([int(first_token)])
        tok = jnp.asarray(first_token, jnp.int32)
        produced = jnp.asarray(1, jnp.int32)
        has_tail = jnp.asarray(False)
        tail = jnp.asarray(0, jnp.int32)
        plen = jnp.asarray(prompt_len, jnp.int32)
        max_new = jnp.asarray(max_new_tokens, jnp.int32)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)
        cancelled = False
        rounds = drafted = accepted = 0
        # host-side live check replays the oracle's post-prefill stop
        # order, so an already-complete request runs zero rounds
        tok_h, produced_h = int(first_token), 1
        while ((eos_id is None or tok_h != eos_id)
               and prompt_len + produced_h < self.max_len
               and produced_h < max_new_tokens):
            if cancel_check is not None and cancel_check():
                cancelled = True
                break
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=_DONATION_WARNING)
                (emit, n_emit, tok, produced, has_tail, tail, caches,
                 dcaches, stopped) = self._round(
                    params, draft_params, caches, dcaches, tok, produced,
                    has_tail, tail, plen, max_new, eos)
            rounds += 1
            n = int(n_emit)                  # one host sync per round
            new = [int(x) for x in np.asarray(emit)[:n]]
            out.extend(new)
            drafted += K
            accepted += n - 1
            if on_segment is not None and new:
                on_segment(new)
            tok_h = new[-1]
            produced_h += n
            if bool(stopped):
                break
        return {"tokens": out, "cancelled": cancelled, "segments": rounds,
                "caches": caches, "draft_caches": dcaches,
                "drafted": drafted, "accepted": accepted}


class LaneDecoder:
    """Lane-batched segmented greedy decoder: ``n_lanes`` concurrent
    requests, one fused ``lax.while_loop`` per segment.

    Each lane is an independent single-request decode riding the model's
    **native batch axis**: the attention caches hold per-sequence ring
    fill levels (``t`` as a (lanes,) vector — models/attention.py), so
    lanes prefilled at different prompt lengths write their next KV at
    different ring slots, take their own RoPE positions and mask their
    own attention windows inside one natively batched ``decode_step``
    (native batching beats a vmap-of-B=1 formulation ~1.5x on CPU — the
    lifted ``(lanes, 1, 1, ...)`` shapes defeat XLA's batched-dot
    kernels).  Per lane the arithmetic is exactly the B=1 computation of
    the serial path, so per-lane token sequences are bitwise-equal to
    independent :class:`FusedDecoder` runs (greedy argmax;
    tests/test_batching.py).

    Segment semantics mirror :class:`FusedDecoder`:

    * the per-lane stop predicate (EOS / ``max_len`` ring budget /
      ``max_new`` request budget) is evaluated on device; a stopped lane
      keeps its token counters frozen (masked ``where`` updates) while
      the surviving lanes continue — its cache slots receive dead writes
      that never reach another lane and that the back-fill prefill
      overwrites wholesale;
    * the segment ends after ``segment_len`` steps or when every lane has
      stopped, and the host syncs once to read the per-lane token buffer;
    * segment boundaries are the **join points**: the host retires
      finished lanes and back-fills vacant cache slots via
      :meth:`insert_lane` (a fresh prefill dropped in at the lane index),
      so the batch composition changes with no recompilation — cache
      shapes are static in ``n_lanes``.
    """

    def __init__(self, lm, max_len: int, n_lanes: int, segment_len: int = 16):
        assert segment_len >= 1 and n_lanes >= 1
        self.lm = lm
        self.max_len = max_len
        self.n_lanes = n_lanes
        self.segment_len = segment_len
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    # ------------------------------------------------------------ lane admin
    def init_lanes(self):
        """Zero caches for ``n_lanes`` sequences, with the attention fill
        levels expanded from the shared scalar to per-lane vectors."""
        caches = self.lm.init_cache(self.n_lanes, self.max_len)
        out = []
        for c in caches:
            if isinstance(c, dict) and "t" in c:
                c = dict(c)
                c["t"] = jnp.zeros(c["t"].shape + (self.n_lanes,),
                                   c["t"].dtype)
            out.append(c)
        return tuple(out)

    def insert_lane(self, lanes, lane: int, cache):
        """Drop a freshly prefilled (B=1) cache pytree into slot ``lane``.

        Batched leaves take the prefill's batch row; the per-lane fill
        level takes the prefill's scalar ``t``.  Shapes must match the
        per-lane slice exactly (prefill with ``pad_to=max_len``), so
        back-filling a retired lane re-uses the compiled segment
        program."""
        def put(big, one):
            if one.ndim == big.ndim:           # (rep, 1, ...) batch leaf
                return big.at[:, lane].set(one[:, 0])
            return big.at[:, lane].set(one)    # (rep,) -> (rep, lanes) fill
        return jax.tree.map(put, lanes, cache)

    def insert_lanes(self, lanes, lane_idx, cache):
        """Batched :meth:`insert_lane`: drop a k-row prefill (vector
        ``prompt_len`` — per-row fill levels, so every leaf already
        carries the batch axis) into lanes ``lane_idx``.  One jitted
        scatter per group instead of 3 eager ops per lane, compiled once
        per group size k."""
        return self._insert(lanes, jnp.asarray(lane_idx, jnp.int32), cache)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _insert(self, lanes, idx, cache):
        return jax.tree.map(lambda big, one: big.at[:, idx].set(one),
                            lanes, cache)

    # -------------------------------------------------------------- segments
    def _live(self, tok, produced, plen, max_new, eos, active):
        """Per-lane continuation mask; the same predicate order as the
        serial oracle (EOS, ring budget, request budget)."""
        return (active
                & (tok != eos)
                & (plen + produced < self.max_len)
                & (produced < max_new))

    def _segment_impl(self, params, caches, tok, produced, plen, max_new,
                      eos, active):
        """Run up to ``segment_len`` steps across all lanes.

        All per-lane carries are (C,) arrays: ``tok`` last emitted token,
        ``produced`` tokens emitted (incl. the prefill token), ``plen``
        prompt length, ``max_new`` request budget, ``active`` lane
        occupancy.  Returns (buf (C, K) int32 -1-padded, tok, produced,
        caches, stopped (C,) bool, dead () int32) — ``dead`` counts
        lane-steps burned on occupied-but-stopped lanes (the masked
        compute a stopped lane wastes until the segment's survivors
        finish; the PR-5 trade-off, reported as ``dead_steps``).
        """
        C, K = self.n_lanes, self.segment_len
        buf0 = jnp.full((C, K), -1, jnp.int32)

        def live(tok, produced):
            return self._live(tok, produced, plen, max_new, eos, active)

        def cond(c):
            i, tok, produced, _, _, _ = c
            return (i < K) & live(tok, produced).any()

        def body(c):
            i, tok, produced, caches, buf, dead = c
            lv = live(tok, produced)
            dead = dead + (active & ~lv).sum().astype(jnp.int32)
            # one natively batched step; stopped lanes compute dead values
            # that the lv masks below keep out of every visible carry
            logits, caches = self.lm.decode_step(
                params, caches, {"tokens": tok.reshape(C, 1)})
            new_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok = jnp.where(lv, new_tok, tok)
            buf = jax.lax.dynamic_update_slice(
                buf, jnp.where(lv, tok, -1)[:, None], (0, i))
            return (i + 1, tok, produced + lv.astype(jnp.int32), caches,
                    buf, dead)

        _, tok, produced, caches, buf, dead = jax.lax.while_loop(
            cond, body,
            (jnp.zeros((), jnp.int32), tok, produced, caches, buf0,
             jnp.zeros((), jnp.int32)))
        return buf, tok, produced, caches, ~live(tok, produced), dead

    def run_segment(self, params, caches, tok, produced, plen, max_new,
                    eos, active, produced_before):
        """One host-level segment call.

        The lane carries (``tok``/``produced``/``plen``/``max_new``/
        ``eos``/``active``) are device arrays — callers keep them
        resident across segments and re-upload only when admission
        changes the lane composition, so a steady-state segment costs one
        jit dispatch plus one host sync (the per-segment conversions were
        the dominant cost of the naive numpy round trip).
        ``produced_before`` is the host-side produced counts going in.

        Returns ``(new_tokens, tok, produced, caches, stopped,
        produced_np, dead_steps)``: ``tok``/``produced`` device arrays
        for the next segment, ``stopped``/``produced_np`` writable host
        copies, ``new_tokens[i]`` the tokens lane ``i`` emitted (in
        order), and ``dead_steps`` the lane-steps this segment burned on
        occupied-but-stopped lanes.
        """
        C = self.n_lanes
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=_DONATION_WARNING)
            buf, tok_j, produced_j, caches, stopped, dead = self._segment(
                params, caches, tok, produced, plen, max_new, eos, active)
        buf_np = np.asarray(buf)                  # one host sync per segment
        produced_np = np.array(produced_j)
        new_tokens = [
            [int(x) for x in buf_np[i, :max(0, int(produced_np[i])
                                            - int(produced_before[i]))]]
            for i in range(C)]
        return (new_tokens, tok_j, produced_j, caches, np.array(stopped),
                produced_np, int(dead))


class PagedLaneDecoder(LaneDecoder):
    """Lane decoder over a block-paged KV pool (serving/paging.py).

    Same segment loop and stop semantics as :class:`LaneDecoder`, but the
    caches are shared physical pools addressed through per-lane block
    tables (models/model.py ``init_paged_cache``): back-fill scatters a
    contiguous prefill cache into the lane's pages, prefix-hit admission
    gathers cached pages back into a contiguous buffer for an extend
    prefill, and page growth/release only rewrites block-table rows.
    Per-lane tokens stay bitwise-equal to the ring path — every logical
    slot holds the same value either way (tests/test_paging.py).
    """

    def __init__(self, lm, max_len: int, n_lanes: int, segment_len: int = 16,
                 *, n_pages: int, page_size: int):
        super().__init__(lm, max_len, n_lanes, segment_len)
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} not a multiple of "
                             f"page_size {page_size}")
        self.n_pages = int(n_pages)        # physical pool incl. trash page 0
        self.page_size = int(page_size)

    # ------------------------------------------------------------ lane admin
    def init_lanes(self):
        """Zero paged caches: pools of ``n_pages`` pages plus per-lane
        block tables (all slots 0 = the pinned trash page)."""
        return self.lm.init_paged_cache(self.n_lanes, self.max_len,
                                        self.n_pages, self.page_size)

    def insert_paged(self, lanes, lane_idx, pcache, bt_rows, tgt):
        """Scatter a k-row contiguous prefill cache into the pool.

        ``pcache`` leaves are (rep, k, Bf, KV, hd) contiguous buffers
        (``_run_prefill_group`` output or an extend prefill); ``bt_rows``
        (k, P) is each lane's full block table; ``tgt`` (k, ceil(Bf/ps))
        maps each Bf-chunk to the physical page that should receive it —
        0 (trash) for pad chunks beyond the prompt and for prefix-hit
        pages whose contents already live in the pool."""
        import jax.numpy as jnp
        return self._insert_paged(lanes, jnp.asarray(lane_idx, jnp.int32),
                                  pcache, jnp.asarray(bt_rows, jnp.int32),
                                  jnp.asarray(tgt, jnp.int32))

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _insert_paged(self, lanes, idx, pcache, bt_rows, tgt):
        ps = self.page_size
        out = []
        for big, one in zip(lanes, pcache):
            rep, k, Bf, KV, hd = one["k"].shape
            nchunk = -(-Bf // ps)
            pad = nchunk * ps - Bf
            ck, cv = one["k"], one["v"]
            if pad:
                widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                ck, cv = jnp.pad(ck, widths), jnp.pad(cv, widths)
            ck = ck.reshape(rep, k * nchunk, ps, KV, hd)
            cv = cv.reshape(rep, k * nchunk, ps, KV, hd)
            tflat = tgt.reshape(-1)
            new = dict(big)
            # page-pool scatter; duplicate indices only ever hit the
            # trash page, where write order is irrelevant
            new["k"] = big["k"].at[:, tflat].set(ck)
            new["v"] = big["v"].at[:, tflat].set(cv)
            tval = one["t"]
            if tval.ndim == 1:             # scalar-fill prefill: (rep,)
                tval = tval[:, None]
            new["t"] = big["t"].at[:, idx].set(tval)
            new["bt"] = big["bt"].at[:, idx].set(bt_rows)
            out.append(new)
        return tuple(out)

    def gather_prefix(self, lanes, pages, prefix_len: int):
        """Materialize cached pages as a contiguous (B=1) prefill cache
        at fill level ``prefix_len`` — the input to an extend prefill.
        ``pages`` (nf,) physical page per logical block; slots past the
        matched prefix may be 0 (trash): the extend prefill overwrites
        them before anything attends there."""
        import jax.numpy as jnp
        return self._gather_prefix(lanes, jnp.asarray(pages, jnp.int32),
                                   jnp.asarray(prefix_len, jnp.int32))

    @functools.partial(jax.jit, static_argnums=0)
    def _gather_prefix(self, lanes, pages, fill):
        out = []
        for c in lanes:
            rep, _, ps, KV, hd = c["k"].shape
            nf = pages.shape[0]
            out.append({
                "k": c["k"][:, pages].reshape(rep, 1, nf * ps, KV, hd),
                "v": c["v"][:, pages].reshape(rep, 1, nf * ps, KV, hd),
                "t": jnp.full((rep,), fill, jnp.int32),
            })
        return tuple(out)

    def set_bt(self, lanes, lane_idx, bt_rows):
        """Rewrite block-table rows in place: page growth extends a busy
        lane's table; release zeroes it so the lane's dead writes land on
        the trash page instead of a reallocated page."""
        import jax.numpy as jnp
        return self._set_bt(lanes, jnp.asarray(lane_idx, jnp.int32),
                            jnp.asarray(bt_rows, jnp.int32))

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _set_bt(self, lanes, idx, rows):
        return tuple({**c, "bt": c["bt"].at[:, idx].set(rows)}
                     for c in lanes)


class _SpecLaneMixin:
    """Draft-verify speculation over a lane decoder's segment loop.

    Mixed into :class:`LaneDecoder` / :class:`PagedLaneDecoder`, this
    replaces the one-token-per-step segment body with *rounds* of
    :class:`SpeculativeDecoder` semantics, vectorized across lanes: every
    round runs the shared draft model ``draft_k`` chained steps for all
    lanes at once, verifies all lanes' chains with ONE multi-position
    target forward (``LM.verify_step`` — K+1 positions against the
    ring/paged KV in a single dispatch), and commits each lane's accepted
    prefix independently.  Per lane the emitted tokens are target
    argmaxes conditioned on accepted context only, so per-lane sequences
    stay bitwise-equal to the non-speculative reference regardless of
    per-lane acceptance (tests/test_speculative.py).

    The lane caches become a dict pytree ``{"tgt", "dr", "has_tail",
    "tail"}``: the target caches in their native layout (ring or paged),
    the draft caches always as a per-lane ring (draft KV is charged
    against the engine's memory budget / page pool by the admission
    layer, but physically lives in its own buffers — it is never
    content-addressed or shared), plus the per-lane catch-up state.  All
    admission-side operations (:meth:`insert_lanes`,
    :meth:`insert_paged`, :meth:`gather_prefix`, :meth:`set_bt`) route to
    the target half unchanged; :meth:`insert_draft` drops the draft
    prefill in and clears the lane's tail.

    Rollback is fill-level-only in both caches: a rejected draft leaves
    stale KV above the committed ``t`` that the verify mask never attends
    and that the next round's writes overwrite in order — no
    recompilation, no cleanup pass.  One caveat inherited from the ring
    layout: a draft chain launched within ``draft_k`` slots of
    ``max_len`` wraps/drops writes, which can only *lower* acceptance on
    the final tokens of a window-filling request, never change emitted
    tokens (the verify forward gates every emission).

    A segment runs ``rounds = max(1, segment_len // (draft_k+1))``
    rounds, so a segment still emits at most ~``segment_len`` tokens per
    lane and host sync frequency is unchanged.  ``run_segment`` keeps the
    base 7-tuple contract and additionally stashes per-lane
    ``last_drafted`` / ``last_accepted`` (host arrays) for the engine's
    acceptance-rate accounting.
    """

    def _init_spec(self, draft_lm, draft_params, draft_k: int):
        if draft_k < 1:
            raise ValueError("draft_k must be >= 1 (K=0 is the fused path)")
        if self.lm.cfg.vocab_size != draft_lm.cfg.vocab_size:
            raise ValueError(
                f"draft/target vocab mismatch: {draft_lm.cfg.vocab_size} "
                f"vs {self.lm.cfg.vocab_size}")
        self.draft_lm = draft_lm
        self.draft_params = draft_params
        self.draft_k = int(draft_k)
        self.rounds = max(1, self.segment_len // (self.draft_k + 1))
        self.last_drafted = np.zeros(self.n_lanes, np.int64)
        self.last_accepted = np.zeros(self.n_lanes, np.int64)
        self._spec_segment = jax.jit(self._spec_segment_impl,
                                     donate_argnums=(2,))

    # ------------------------------------------------------------ lane admin
    def init_lanes(self):
        dr = []
        for c in self.draft_lm.init_cache(self.n_lanes, self.max_len):
            if isinstance(c, dict) and "t" in c:
                c = dict(c)
                c["t"] = jnp.zeros(c["t"].shape + (self.n_lanes,),
                                   c["t"].dtype)
            dr.append(c)
        return {"tgt": super().init_lanes(), "dr": tuple(dr),
                "has_tail": jnp.zeros((self.n_lanes,), bool),
                "tail": jnp.zeros((self.n_lanes,), jnp.int32)}

    def insert_lane(self, lanes, lane, cache):
        return {**lanes,
                "tgt": super().insert_lane(lanes["tgt"], lane, cache)}

    def insert_lanes(self, lanes, lane_idx, cache):
        return {**lanes,
                "tgt": super().insert_lanes(lanes["tgt"], lane_idx, cache)}

    def insert_draft(self, lanes, lane_idx, cache):
        """Drop a k-row draft prefill into lanes ``lane_idx`` and clear
        their catch-up tails (a fresh request has no pending draft)."""
        idx = jnp.asarray(lane_idx, jnp.int32)
        return {**lanes, "dr": self._insert(lanes["dr"], idx, cache),
                "has_tail": lanes["has_tail"].at[idx].set(False)}

    def gather_prefix(self, lanes, pages, prefix_len: int):
        return super().gather_prefix(lanes["tgt"], pages, prefix_len)

    def insert_paged(self, lanes, lane_idx, pcache, bt_rows, tgt):
        return {**lanes, "tgt": super().insert_paged(
            lanes["tgt"], lane_idx, pcache, bt_rows, tgt)}

    def set_bt(self, lanes, lane_idx, bt_rows):
        return {**lanes,
                "tgt": super().set_bt(lanes["tgt"], lane_idx, bt_rows)}

    # -------------------------------------------------------------- segments
    def _spec_segment_impl(self, params, draft_params, caches, tok,
                           produced, plen, max_new, eos, active):
        """Run ``rounds`` draft-verify rounds across all lanes.

        Same carries as :meth:`LaneDecoder._segment_impl`; returns
        (buf (C, rounds*(K+1)) int32 -1-padded, tok, produced, caches,
        stopped, dead, drafted (C,), accepted (C,)) — ``dead`` counts
        verify positions burned on occupied-but-stopped lanes; wasted
        *draft* positions are ``drafted - accepted``, accounted by the
        engine so the split stays visible in stats.
        """
        C, K, R = self.n_lanes, self.draft_k, self.rounds
        W = K + 1
        BUF = R * W
        idx_w = jnp.arange(W, dtype=jnp.int32)
        buf0 = jnp.full((C, BUF), -1, jnp.int32)
        eos_c = eos[:, None] if jnp.ndim(eos) == 1 else eos

        def live(tok, produced):
            return self._live(tok, produced, plen, max_new, eos, active)

        def cond(c):
            r, tok, produced = c[0], c[1], c[2]
            return (r < R) & live(tok, produced).any()

        def body(c):
            (r, tok, produced, tgtc, drc, has_tail, tail, buf, wp, dead,
             drafted, accepted) = c
            lv = live(tok, produced)
            lvi = lv.astype(jnp.int32)
            dead = dead + W * (active & ~lv).sum().astype(jnp.int32)

            # --- draft: catch-up step + K chained steps ----------------
            # Catch-up consumes a full-accept round's unconsumed tail;
            # lanes without one feed their pending token as a dummy (the
            # fill reset below voids the slot advance, the duplicate
            # write is overwritten by the chain's first real write, and
            # step-0 logits are never used).
            dr_t0 = [dc["t"] for dc in drc]
            feed0 = jnp.where(has_tail, tail, tok)
            _, drc = self.draft_lm.decode_step(
                draft_params, drc, {"tokens": feed0.reshape(C, 1)})
            ht = has_tail.astype(jnp.int32)
            drc = tuple({**dc, "t": t0 + ht[None, :]}
                        for dc, t0 in zip(drc, dr_t0))

            def dstep(carry, _):
                cur, dc = carry
                lg, dc = self.draft_lm.decode_step(
                    draft_params, dc, {"tokens": cur.reshape(C, 1)})
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                return (nxt, dc), nxt

            (_, drc), d = jax.lax.scan(dstep, (tok, drc), None, length=K)
            d = d.T                                          # (C, K)

            # --- verify: one multi-position target forward -------------
            base_t = tgtc[0]["t"][0]                         # (C,) fills
            feed = jnp.concatenate([tok[:, None], d], axis=1)
            vlog, tgtc = self.lm.verify_step(params, tgtc,
                                             {"tokens": feed})
            a = jnp.argmax(vlog, axis=-1).astype(jnp.int32)  # (C, W)

            # --- acceptance: longest matching prefix, oracle stops -----
            ok = (d == a[:, :K]).astype(jnp.int32)
            m_chain = jnp.cumprod(ok, axis=1).sum(axis=1)
            cap = jnp.minimum(m_chain + 1,
                              jnp.minimum(self.max_len - plen - produced,
                                          max_new - produced))
            is_eos = (a == eos_c) & (idx_w[None, :] < cap[:, None])
            n_emit = jnp.where(is_eos.any(axis=1),
                               jnp.argmax(is_eos, axis=1)
                               .astype(jnp.int32) + 1, cap)
            n_emit = jnp.where(lv, n_emit, 0)

            # --- commit ------------------------------------------------
            valid = idx_w[None, :] < n_emit[:, None]
            slot = wp[:, None] + idx_w[None, :]
            hit = ((jnp.arange(BUF, dtype=jnp.int32)[None, None, :]
                    == slot[:, :, None]) & valid[:, :, None])
            buf = jnp.where(hit.any(axis=1),
                            (a[:, :, None] * hit).sum(axis=1), buf)
            wp = wp + n_emit
            last = jnp.take_along_axis(
                a, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)[:, 0]
            tok = jnp.where(lv, last, tok)
            produced = produced + n_emit
            tgtc = tuple({**tc, "t": tc["t"] + n_emit[None, :]}
                         for tc in tgtc)
            # draft keeps the accepted drafts only; stopped lanes restore
            # their pre-round fill (their chain steps were dead writes)
            n_keep = jnp.minimum(n_emit, K)
            drc = tuple(
                {**dc, "t": jnp.where(lv[None, :],
                                      (base_t + n_keep)[None, :], t0)}
                for dc, t0 in zip(drc, dr_t0))
            full = n_emit == W
            has_tail = jnp.where(lv, full, has_tail)
            tail = jnp.where(lv & full, d[:, K - 1], tail)
            drafted = drafted + K * lvi
            accepted = accepted + n_emit - lvi
            return (r + 1, tok, produced, tgtc, drc, has_tail, tail, buf,
                    wp, dead, drafted, accepted)

        z = jnp.zeros((C,), jnp.int32)
        (_, tok, produced, tgtc, drc, has_tail, tail, buf, _, dead,
         drafted, accepted) = jax.lax.while_loop(
            cond, body,
            (jnp.zeros((), jnp.int32), tok, produced, caches["tgt"],
             caches["dr"], caches["has_tail"], caches["tail"], buf0, z,
             jnp.zeros((), jnp.int32), z, z))
        caches = {"tgt": tgtc, "dr": drc, "has_tail": has_tail,
                  "tail": tail}
        return (buf, tok, produced, caches, ~live(tok, produced), dead,
                drafted, accepted)

    def run_segment(self, params, caches, tok, produced, plen, max_new,
                    eos, active, produced_before):
        """Same contract as :meth:`LaneDecoder.run_segment`; additionally
        stashes per-lane ``last_drafted`` / ``last_accepted`` host arrays
        for the engine's acceptance accounting."""
        C = self.n_lanes
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=_DONATION_WARNING)
            (buf, tok_j, produced_j, caches, stopped, dead, drafted,
             accepted) = self._spec_segment(
                params, self.draft_params, caches, tok, produced, plen,
                max_new, eos, active)
        buf_np = np.asarray(buf)                  # one host sync per segment
        produced_np = np.array(produced_j)
        self.last_drafted = np.array(drafted)
        self.last_accepted = np.array(accepted)
        new_tokens = [
            [int(x) for x in buf_np[i, :max(0, int(produced_np[i])
                                            - int(produced_before[i]))]]
            for i in range(C)]
        return (new_tokens, tok_j, produced_j, caches, np.array(stopped),
                produced_np, int(dead))


class SpeculativeLaneDecoder(_SpecLaneMixin, LaneDecoder):
    """Ring-cache lane decoder with draft-verify speculation."""

    def __init__(self, lm, draft_lm, draft_params, max_len: int,
                 n_lanes: int, segment_len: int = 16, *, draft_k: int):
        LaneDecoder.__init__(self, lm, max_len, n_lanes, segment_len)
        self._init_spec(draft_lm, draft_params, draft_k)


class SpeculativePagedLaneDecoder(_SpecLaneMixin, PagedLaneDecoder):
    """Block-paged lane decoder with draft-verify speculation.  The
    target KV stays paged; the draft KV rides a per-lane ring whose
    footprint the paged admission layer charges as anonymous pages."""

    def __init__(self, lm, draft_lm, draft_params, max_len: int,
                 n_lanes: int, segment_len: int = 16, *, n_pages: int,
                 page_size: int, draft_k: int):
        PagedLaneDecoder.__init__(self, lm, max_len, n_lanes, segment_len,
                                  n_pages=n_pages, page_size=page_size)
        self._init_spec(draft_lm, draft_params, draft_k)


def geometric_buckets(max_len: int, floor: int = 16) -> tuple:
    """Prefill padding buckets: powers of two from ``floor`` up to and
    including ``max_len`` — a mixed-length admission stream compiles
    O(log(max_len)) prefill programs instead of one per distinct length."""
    buckets = []
    b = floor
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return tuple(buckets)


def bucket_for(n: int, buckets) -> int:
    """Smallest bucket >= n; lengths beyond the last bucket prefill at
    exact length (the seed behavior — the decoder can't extend past
    ``max_len`` anyway, so rounding such a prompt up to a bigger pow2
    would only buy a compile of a cache shape that is never decoded)."""
    for b in buckets:
        if n <= b:
            return b
    return n
