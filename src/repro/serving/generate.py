"""Fused on-device greedy generation: segmented ``lax.while_loop`` decode.

The seed decode loop (kept as ``RealEngine.generate_reference``) runs one
jitted ``decode_step`` per token and syncs to host every step — ``np.argmax``
on the logits plus a re-upload of the sampled token — so per-token cost on
small models is dispatch latency, not compute.  :class:`FusedDecoder`
replaces it with a *segmented* device loop:

* one jitted call runs up to ``segment_len`` decode steps in a
  ``lax.while_loop`` whose carry holds the current token, the KV caches and
  the emitted-token buffer — tokens never leave the device inside a segment;
* the EOS / ``max_len`` / ``max_new`` stop condition is evaluated on device
  in the loop predicate, mirroring the oracle's Python ``break``s exactly
  (same check order, so token sequences are bitwise-comparable);
* the KV caches are **donated** into the segment call
  (``donate_argnums``), so on backends with donation support the ring
  buffers update in place instead of being copied once per call;
* the host syncs once per segment to read the emitted tokens and check the
  engine's cancel flag (§3.4 drain semantics: a disconnect observed between
  segments stops generation at the segment boundary, freeing the serial
  dispatch slot within ``segment_len`` tokens).

Dispatch overhead is therefore amortized to ``1/segment_len`` of the seed
loop's; ``benchmarks/serve_bench.py`` measures the ratio and writes it to
``BENCH_serve.json``.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Older CPU jaxlibs ignore donation with a warning; the fused loop is still
# correct (the copy just reappears).  Suppressed around the segment call
# only — not globally — so applications keep the signal for their own jits.
_DONATION_WARNING = "Some donated buffers were not usable"


class FusedDecoder:
    """Device-resident segmented greedy decoder for one ``LM``.

    One instance per (model, max_len, segment_len); the segment function is
    compiled once per cache shape (i.e. per cache capacity x batch size).
    """

    def __init__(self, lm, max_len: int, segment_len: int = 16):
        assert segment_len >= 1
        self.lm = lm
        self.max_len = max_len
        self.segment_len = segment_len
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    def _segment_impl(self, params, caches, tok, produced, prompt_len,
                      max_new, eos):
        """Run up to ``segment_len`` decode steps on device.

        tok: () int32 last emitted token; produced: () int32 tokens emitted
        so far (including the prefill token); eos: () int32 (-1 = disabled).
        Returns (buf (K,) int32 with -1 padding, tok, produced, caches,
        stopped) — ``stopped`` True when the generation-level stop condition
        holds, i.e. the host should not launch another segment.
        """
        K = self.segment_len
        max_len = self.max_len
        buf0 = jnp.full((K,), -1, jnp.int32)

        def live(tok, produced):
            # The oracle's break conditions, in order: EOS, cache/window
            # budget, request budget.
            return ((tok != eos)
                    & (prompt_len + produced < max_len)
                    & (produced < max_new))

        def cond(c):
            i, tok, produced, _, _ = c
            return (i < K) & live(tok, produced)

        def body(c):
            i, tok, produced, caches, buf = c
            logits, caches = self.lm.decode_step(
                params, caches, {"tokens": tok.reshape(1, 1)})
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            buf = jax.lax.dynamic_update_slice(buf, tok[None], (i,))
            return i + 1, tok, produced + 1, caches, buf

        _, tok, produced, caches, buf = jax.lax.while_loop(
            cond, body,
            (jnp.zeros((), jnp.int32), tok, produced, caches, buf0))
        return buf, tok, produced, caches, ~live(tok, produced)

    def decode(self, params, caches, first_token: int, prompt_len: int,
               max_new_tokens: int, eos_id: Optional[int] = None,
               cancel_check=None, on_segment=None) -> dict:
        """Greedy-decode from a prefilled cache.

        ``first_token`` is the prefill argmax (already emitted).  Returns
        {"tokens": [first_token, ...], "cancelled": bool, "segments": int,
        "caches": final cache pytree}.

        ``on_segment(new_tokens)`` fires at every host sync with the
        tokens emitted since the previous call — the prefill token before
        the first segment, then one call per segment.  This is the SSE
        streaming hook: segment boundaries are the only points where
        tokens reach the host, so they are the natural flush granularity
        for the sidecar (and the same join points where cancellation and
        injected crashes land).  Exceptions from the callback propagate —
        emission is part of serving the request.
        """
        out = [int(first_token)]
        if on_segment is not None:
            on_segment([int(first_token)])
        tok = jnp.asarray(first_token, jnp.int32)
        produced = jnp.asarray(1, jnp.int32)
        plen = jnp.asarray(prompt_len, jnp.int32)
        max_new = jnp.asarray(max_new_tokens, jnp.int32)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)
        cancelled = False
        segments = 0
        # The first segment's predicate replays the oracle's post-prefill
        # checks, so a request that is already complete runs zero steps.
        while True:
            if cancel_check is not None and cancel_check():
                cancelled = True
                break
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=_DONATION_WARNING)
                buf, tok, produced, caches, stopped = self._segment(
                    params, caches, tok, produced, plen, max_new, eos)
            segments += 1
            n_new = int(produced) - len(out)     # one host sync per segment
            buf_np = np.asarray(buf)
            new = [int(x) for x in buf_np[:n_new]]
            out.extend(new)
            if on_segment is not None and new:
                on_segment(new)
            if bool(stopped):
                break
        return {"tokens": out, "cancelled": cancelled, "segments": segments,
                "caches": caches}


class LaneDecoder:
    """Lane-batched segmented greedy decoder: ``n_lanes`` concurrent
    requests, one fused ``lax.while_loop`` per segment.

    Each lane is an independent single-request decode riding the model's
    **native batch axis**: the attention caches hold per-sequence ring
    fill levels (``t`` as a (lanes,) vector — models/attention.py), so
    lanes prefilled at different prompt lengths write their next KV at
    different ring slots, take their own RoPE positions and mask their
    own attention windows inside one natively batched ``decode_step``
    (native batching beats a vmap-of-B=1 formulation ~1.5x on CPU — the
    lifted ``(lanes, 1, 1, ...)`` shapes defeat XLA's batched-dot
    kernels).  Per lane the arithmetic is exactly the B=1 computation of
    the serial path, so per-lane token sequences are bitwise-equal to
    independent :class:`FusedDecoder` runs (greedy argmax;
    tests/test_batching.py).

    Segment semantics mirror :class:`FusedDecoder`:

    * the per-lane stop predicate (EOS / ``max_len`` ring budget /
      ``max_new`` request budget) is evaluated on device; a stopped lane
      keeps its token counters frozen (masked ``where`` updates) while
      the surviving lanes continue — its cache slots receive dead writes
      that never reach another lane and that the back-fill prefill
      overwrites wholesale;
    * the segment ends after ``segment_len`` steps or when every lane has
      stopped, and the host syncs once to read the per-lane token buffer;
    * segment boundaries are the **join points**: the host retires
      finished lanes and back-fills vacant cache slots via
      :meth:`insert_lane` (a fresh prefill dropped in at the lane index),
      so the batch composition changes with no recompilation — cache
      shapes are static in ``n_lanes``.
    """

    def __init__(self, lm, max_len: int, n_lanes: int, segment_len: int = 16):
        assert segment_len >= 1 and n_lanes >= 1
        self.lm = lm
        self.max_len = max_len
        self.n_lanes = n_lanes
        self.segment_len = segment_len
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    # ------------------------------------------------------------ lane admin
    def init_lanes(self):
        """Zero caches for ``n_lanes`` sequences, with the attention fill
        levels expanded from the shared scalar to per-lane vectors."""
        caches = self.lm.init_cache(self.n_lanes, self.max_len)
        out = []
        for c in caches:
            if isinstance(c, dict) and "t" in c:
                c = dict(c)
                c["t"] = jnp.zeros(c["t"].shape + (self.n_lanes,),
                                   c["t"].dtype)
            out.append(c)
        return tuple(out)

    def insert_lane(self, lanes, lane: int, cache):
        """Drop a freshly prefilled (B=1) cache pytree into slot ``lane``.

        Batched leaves take the prefill's batch row; the per-lane fill
        level takes the prefill's scalar ``t``.  Shapes must match the
        per-lane slice exactly (prefill with ``pad_to=max_len``), so
        back-filling a retired lane re-uses the compiled segment
        program."""
        def put(big, one):
            if one.ndim == big.ndim:           # (rep, 1, ...) batch leaf
                return big.at[:, lane].set(one[:, 0])
            return big.at[:, lane].set(one)    # (rep,) -> (rep, lanes) fill
        return jax.tree.map(put, lanes, cache)

    def insert_lanes(self, lanes, lane_idx, cache):
        """Batched :meth:`insert_lane`: drop a k-row prefill (vector
        ``prompt_len`` — per-row fill levels, so every leaf already
        carries the batch axis) into lanes ``lane_idx``.  One jitted
        scatter per group instead of 3 eager ops per lane, compiled once
        per group size k."""
        return self._insert(lanes, jnp.asarray(lane_idx, jnp.int32), cache)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _insert(self, lanes, idx, cache):
        return jax.tree.map(lambda big, one: big.at[:, idx].set(one),
                            lanes, cache)

    # -------------------------------------------------------------- segments
    def _live(self, tok, produced, plen, max_new, eos, active):
        """Per-lane continuation mask; the same predicate order as the
        serial oracle (EOS, ring budget, request budget)."""
        return (active
                & (tok != eos)
                & (plen + produced < self.max_len)
                & (produced < max_new))

    def _segment_impl(self, params, caches, tok, produced, plen, max_new,
                      eos, active):
        """Run up to ``segment_len`` steps across all lanes.

        All per-lane carries are (C,) arrays: ``tok`` last emitted token,
        ``produced`` tokens emitted (incl. the prefill token), ``plen``
        prompt length, ``max_new`` request budget, ``active`` lane
        occupancy.  Returns (buf (C, K) int32 -1-padded, tok, produced,
        caches, stopped (C,) bool, dead () int32) — ``dead`` counts
        lane-steps burned on occupied-but-stopped lanes (the masked
        compute a stopped lane wastes until the segment's survivors
        finish; the PR-5 trade-off, reported as ``dead_steps``).
        """
        C, K = self.n_lanes, self.segment_len
        buf0 = jnp.full((C, K), -1, jnp.int32)

        def live(tok, produced):
            return self._live(tok, produced, plen, max_new, eos, active)

        def cond(c):
            i, tok, produced, _, _, _ = c
            return (i < K) & live(tok, produced).any()

        def body(c):
            i, tok, produced, caches, buf, dead = c
            lv = live(tok, produced)
            dead = dead + (active & ~lv).sum().astype(jnp.int32)
            # one natively batched step; stopped lanes compute dead values
            # that the lv masks below keep out of every visible carry
            logits, caches = self.lm.decode_step(
                params, caches, {"tokens": tok.reshape(C, 1)})
            new_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok = jnp.where(lv, new_tok, tok)
            buf = jax.lax.dynamic_update_slice(
                buf, jnp.where(lv, tok, -1)[:, None], (0, i))
            return (i + 1, tok, produced + lv.astype(jnp.int32), caches,
                    buf, dead)

        _, tok, produced, caches, buf, dead = jax.lax.while_loop(
            cond, body,
            (jnp.zeros((), jnp.int32), tok, produced, caches, buf0,
             jnp.zeros((), jnp.int32)))
        return buf, tok, produced, caches, ~live(tok, produced), dead

    def run_segment(self, params, caches, tok, produced, plen, max_new,
                    eos, active, produced_before):
        """One host-level segment call.

        The lane carries (``tok``/``produced``/``plen``/``max_new``/
        ``eos``/``active``) are device arrays — callers keep them
        resident across segments and re-upload only when admission
        changes the lane composition, so a steady-state segment costs one
        jit dispatch plus one host sync (the per-segment conversions were
        the dominant cost of the naive numpy round trip).
        ``produced_before`` is the host-side produced counts going in.

        Returns ``(new_tokens, tok, produced, caches, stopped,
        produced_np, dead_steps)``: ``tok``/``produced`` device arrays
        for the next segment, ``stopped``/``produced_np`` writable host
        copies, ``new_tokens[i]`` the tokens lane ``i`` emitted (in
        order), and ``dead_steps`` the lane-steps this segment burned on
        occupied-but-stopped lanes.
        """
        C = self.n_lanes
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=_DONATION_WARNING)
            buf, tok_j, produced_j, caches, stopped, dead = self._segment(
                params, caches, tok, produced, plen, max_new, eos, active)
        buf_np = np.asarray(buf)                  # one host sync per segment
        produced_np = np.array(produced_j)
        new_tokens = [
            [int(x) for x in buf_np[i, :max(0, int(produced_np[i])
                                            - int(produced_before[i]))]]
            for i in range(C)]
        return (new_tokens, tok_j, produced_j, caches, np.array(stopped),
                produced_np, int(dead))


class PagedLaneDecoder(LaneDecoder):
    """Lane decoder over a block-paged KV pool (serving/paging.py).

    Same segment loop and stop semantics as :class:`LaneDecoder`, but the
    caches are shared physical pools addressed through per-lane block
    tables (models/model.py ``init_paged_cache``): back-fill scatters a
    contiguous prefill cache into the lane's pages, prefix-hit admission
    gathers cached pages back into a contiguous buffer for an extend
    prefill, and page growth/release only rewrites block-table rows.
    Per-lane tokens stay bitwise-equal to the ring path — every logical
    slot holds the same value either way (tests/test_paging.py).
    """

    def __init__(self, lm, max_len: int, n_lanes: int, segment_len: int = 16,
                 *, n_pages: int, page_size: int):
        super().__init__(lm, max_len, n_lanes, segment_len)
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} not a multiple of "
                             f"page_size {page_size}")
        self.n_pages = int(n_pages)        # physical pool incl. trash page 0
        self.page_size = int(page_size)

    # ------------------------------------------------------------ lane admin
    def init_lanes(self):
        """Zero paged caches: pools of ``n_pages`` pages plus per-lane
        block tables (all slots 0 = the pinned trash page)."""
        return self.lm.init_paged_cache(self.n_lanes, self.max_len,
                                        self.n_pages, self.page_size)

    def insert_paged(self, lanes, lane_idx, pcache, bt_rows, tgt):
        """Scatter a k-row contiguous prefill cache into the pool.

        ``pcache`` leaves are (rep, k, Bf, KV, hd) contiguous buffers
        (``_run_prefill_group`` output or an extend prefill); ``bt_rows``
        (k, P) is each lane's full block table; ``tgt`` (k, ceil(Bf/ps))
        maps each Bf-chunk to the physical page that should receive it —
        0 (trash) for pad chunks beyond the prompt and for prefix-hit
        pages whose contents already live in the pool."""
        import jax.numpy as jnp
        return self._insert_paged(lanes, jnp.asarray(lane_idx, jnp.int32),
                                  pcache, jnp.asarray(bt_rows, jnp.int32),
                                  jnp.asarray(tgt, jnp.int32))

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _insert_paged(self, lanes, idx, pcache, bt_rows, tgt):
        ps = self.page_size
        out = []
        for big, one in zip(lanes, pcache):
            rep, k, Bf, KV, hd = one["k"].shape
            nchunk = -(-Bf // ps)
            pad = nchunk * ps - Bf
            ck, cv = one["k"], one["v"]
            if pad:
                widths = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                ck, cv = jnp.pad(ck, widths), jnp.pad(cv, widths)
            ck = ck.reshape(rep, k * nchunk, ps, KV, hd)
            cv = cv.reshape(rep, k * nchunk, ps, KV, hd)
            tflat = tgt.reshape(-1)
            new = dict(big)
            # page-pool scatter; duplicate indices only ever hit the
            # trash page, where write order is irrelevant
            new["k"] = big["k"].at[:, tflat].set(ck)
            new["v"] = big["v"].at[:, tflat].set(cv)
            tval = one["t"]
            if tval.ndim == 1:             # scalar-fill prefill: (rep,)
                tval = tval[:, None]
            new["t"] = big["t"].at[:, idx].set(tval)
            new["bt"] = big["bt"].at[:, idx].set(bt_rows)
            out.append(new)
        return tuple(out)

    def gather_prefix(self, lanes, pages, prefix_len: int):
        """Materialize cached pages as a contiguous (B=1) prefill cache
        at fill level ``prefix_len`` — the input to an extend prefill.
        ``pages`` (nf,) physical page per logical block; slots past the
        matched prefix may be 0 (trash): the extend prefill overwrites
        them before anything attends there."""
        import jax.numpy as jnp
        return self._gather_prefix(lanes, jnp.asarray(pages, jnp.int32),
                                   jnp.asarray(prefix_len, jnp.int32))

    @functools.partial(jax.jit, static_argnums=0)
    def _gather_prefix(self, lanes, pages, fill):
        out = []
        for c in lanes:
            rep, _, ps, KV, hd = c["k"].shape
            nf = pages.shape[0]
            out.append({
                "k": c["k"][:, pages].reshape(rep, 1, nf * ps, KV, hd),
                "v": c["v"][:, pages].reshape(rep, 1, nf * ps, KV, hd),
                "t": jnp.full((rep,), fill, jnp.int32),
            })
        return tuple(out)

    def set_bt(self, lanes, lane_idx, bt_rows):
        """Rewrite block-table rows in place: page growth extends a busy
        lane's table; release zeroes it so the lane's dead writes land on
        the trash page instead of a reallocated page."""
        import jax.numpy as jnp
        return self._set_bt(lanes, jnp.asarray(lane_idx, jnp.int32),
                            jnp.asarray(bt_rows, jnp.int32))

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _set_bt(self, lanes, idx, rows):
        return tuple({**c, "bt": c["bt"].at[:, idx].set(rows)}
                     for c in lanes)


def geometric_buckets(max_len: int, floor: int = 16) -> tuple:
    """Prefill padding buckets: powers of two from ``floor`` up to and
    including ``max_len`` — a mixed-length admission stream compiles
    O(log(max_len)) prefill programs instead of one per distinct length."""
    buckets = []
    b = floor
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return tuple(buckets)


def bucket_for(n: int, buckets) -> int:
    """Smallest bucket >= n; lengths beyond the last bucket prefill at
    exact length (the seed behavior — the decoder can't extend past
    ``max_len`` anyway, so rounding such a prompt up to a bigger pow2
    would only buy a compile of a cache shape that is never decoded)."""
    for b in buckets:
        if n <= b:
            return b
    return n
