"""Fused on-device greedy generation: segmented ``lax.while_loop`` decode.

The seed decode loop (kept as ``RealEngine.generate_reference``) runs one
jitted ``decode_step`` per token and syncs to host every step — ``np.argmax``
on the logits plus a re-upload of the sampled token — so per-token cost on
small models is dispatch latency, not compute.  :class:`FusedDecoder`
replaces it with a *segmented* device loop:

* one jitted call runs up to ``segment_len`` decode steps in a
  ``lax.while_loop`` whose carry holds the current token, the KV caches and
  the emitted-token buffer — tokens never leave the device inside a segment;
* the EOS / ``max_len`` / ``max_new`` stop condition is evaluated on device
  in the loop predicate, mirroring the oracle's Python ``break``s exactly
  (same check order, so token sequences are bitwise-comparable);
* the KV caches are **donated** into the segment call
  (``donate_argnums``), so on backends with donation support the ring
  buffers update in place instead of being copied once per call;
* the host syncs once per segment to read the emitted tokens and check the
  engine's cancel flag (§3.4 drain semantics: a disconnect observed between
  segments stops generation at the segment boundary, freeing the serial
  dispatch slot within ``segment_len`` tokens).

Dispatch overhead is therefore amortized to ``1/segment_len`` of the seed
loop's; ``benchmarks/serve_bench.py`` measures the ratio and writes it to
``BENCH_serve.json``.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Older CPU jaxlibs ignore donation with a warning; the fused loop is still
# correct (the copy just reappears).  Suppressed around the segment call
# only — not globally — so applications keep the signal for their own jits.
_DONATION_WARNING = "Some donated buffers were not usable"


class FusedDecoder:
    """Device-resident segmented greedy decoder for one ``LM``.

    One instance per (model, max_len, segment_len); the segment function is
    compiled once per cache shape (i.e. per cache capacity x batch size).
    """

    def __init__(self, lm, max_len: int, segment_len: int = 16):
        assert segment_len >= 1
        self.lm = lm
        self.max_len = max_len
        self.segment_len = segment_len
        self._segment = jax.jit(self._segment_impl, donate_argnums=(1,))

    def _segment_impl(self, params, caches, tok, produced, prompt_len,
                      max_new, eos):
        """Run up to ``segment_len`` decode steps on device.

        tok: () int32 last emitted token; produced: () int32 tokens emitted
        so far (including the prefill token); eos: () int32 (-1 = disabled).
        Returns (buf (K,) int32 with -1 padding, tok, produced, caches,
        stopped) — ``stopped`` True when the generation-level stop condition
        holds, i.e. the host should not launch another segment.
        """
        K = self.segment_len
        max_len = self.max_len
        buf0 = jnp.full((K,), -1, jnp.int32)

        def live(tok, produced):
            # The oracle's break conditions, in order: EOS, cache/window
            # budget, request budget.
            return ((tok != eos)
                    & (prompt_len + produced < max_len)
                    & (produced < max_new))

        def cond(c):
            i, tok, produced, _, _ = c
            return (i < K) & live(tok, produced)

        def body(c):
            i, tok, produced, caches, buf = c
            logits, caches = self.lm.decode_step(
                params, caches, {"tokens": tok.reshape(1, 1)})
            tok = jnp.argmax(logits[0]).astype(jnp.int32)
            buf = jax.lax.dynamic_update_slice(buf, tok[None], (i,))
            return i + 1, tok, produced + 1, caches, buf

        _, tok, produced, caches, buf = jax.lax.while_loop(
            cond, body,
            (jnp.zeros((), jnp.int32), tok, produced, caches, buf0))
        return buf, tok, produced, caches, ~live(tok, produced)

    def decode(self, params, caches, first_token: int, prompt_len: int,
               max_new_tokens: int, eos_id: Optional[int] = None,
               cancel_check=None) -> dict:
        """Greedy-decode from a prefilled cache.

        ``first_token`` is the prefill argmax (already emitted).  Returns
        {"tokens": [first_token, ...], "cancelled": bool, "segments": int,
        "caches": final cache pytree}.
        """
        out = [int(first_token)]
        tok = jnp.asarray(first_token, jnp.int32)
        produced = jnp.asarray(1, jnp.int32)
        plen = jnp.asarray(prompt_len, jnp.int32)
        max_new = jnp.asarray(max_new_tokens, jnp.int32)
        eos = jnp.asarray(-1 if eos_id is None else eos_id, jnp.int32)
        cancelled = False
        segments = 0
        # The first segment's predicate replays the oracle's post-prefill
        # checks, so a request that is already complete runs zero steps.
        while True:
            if cancel_check is not None and cancel_check():
                cancelled = True
                break
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=_DONATION_WARNING)
                buf, tok, produced, caches, stopped = self._segment(
                    params, caches, tok, produced, plen, max_new, eos)
            segments += 1
            n_new = int(produced) - len(out)     # one host sync per segment
            buf_np = np.asarray(buf)
            out.extend(int(x) for x in buf_np[:n_new])
            if bool(stopped):
                break
        return {"tokens": out, "cancelled": cancelled, "segments": segments,
                "caches": caches}


def geometric_buckets(max_len: int, floor: int = 16) -> tuple:
    """Prefill padding buckets: powers of two from ``floor`` up to and
    including ``max_len`` — a mixed-length admission stream compiles
    O(log(max_len)) prefill programs instead of one per distinct length."""
    buckets = []
    b = floor
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return tuple(buckets)


def bucket_for(n: int, buckets) -> int:
    """Smallest bucket >= n; lengths beyond the last bucket prefill at
    exact length (the seed behavior — the decoder can't extend past
    ``max_len`` anyway, so rounding such a prompt up to a bigger pow2
    would only buy a compile of a cache shape that is never decoded)."""
    for b in buckets:
        if n <= b:
            return b
    return n
