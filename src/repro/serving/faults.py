"""Deterministic fault injection + fault handling for the serving stack.

The paper positions Clairvoyant as a drop-in sidecar for flaky local
serial backends (Ollama, llama.cpp) — environments where the backend
crashes mid-generation, a replica stalls, or the predictor sees
out-of-distribution inputs.  This module provides both halves of the
robustness story:

**Injection** — a seeded :class:`FaultPlan` schedules faults ahead of
time, so every chaos run is reproducible bit-for-bit:

* ``crash`` — the engine dies mid-generation.  On sim drains the crash
  fires when its virtual-time trigger falls inside a service interval;
  on real engines it fires at a fused-decode segment boundary (the
  ``after_polls``-th cancel poll), raising :class:`EngineCrash` out of
  ``generate``/``run_lanes``.  ``repair_s`` keeps the replica down.
* ``lane_crash`` — batched engines only: one decode lane dies at a
  segment boundary; the lane is evicted and back-filled, and the server
  requeues the victim (work-conserving resume via re-prefill).
* ``stall`` — a straggler window: services dispatched inside
  ``[at, at + duration)`` are stretched by ``factor`` (sim/DES drains).
* ``predictor_down`` — admission-time predictor outage window: the
  server degrades to FCFS admission instead of erroring (see
  ``ClairvoyantServer.degraded``), recovering when the window closes.
* ``transient`` — a retryable backend error at dispatch time
  (:class:`TransientBackendError`); each spec fails exactly one attempt.
* ``overflow`` — admission-queue overflow window: submissions during
  ``[at, at + duration)`` are shed with ``status="shed"``.

**Handling** — the machinery the server/router thread through:

* :class:`RetryPolicy` — jittered exponential backoff with a bounded
  retry count (seeded jitter: deterministic across runs).
* :class:`CircuitBreaker` — per-replica closed -> open -> half-open
  breaker; ``open`` after ``failure_threshold`` consecutive failures,
  a single probe is admitted after ``recovery_s``, and a probe success
  closes the breaker (feeds ``ReplicaState.healthy`` in core/router.py).
* Deadline budgets / load shedding live in the server (``deadline_s``):
  a request whose queue wait already exceeds its budget at dispatch
  time is shed with a terminal response instead of served.

The invariant all of this protects: **no request is ever silently
lost** — every submitted request terminates with exactly one terminal
:class:`~repro.serving.openai_api.CompletionResponse`
(``ok | shed | failed | timeout | cancelled``).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


# --------------------------------------------------------------------------
# Exceptions
# --------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base class for injected/handled backend faults."""


class TransientBackendError(FaultError):
    """Retryable backend error at dispatch time (e.g. a dropped
    connection to the sidecar's backend)."""


class EngineCrash(FaultError):
    """The engine died mid-generation; in-flight work is lost and the
    replica is down for ``repair_s``."""

    def __init__(self, msg: str = "engine crash", at: float = 0.0,
                 repair_s: float = 0.0):
        super().__init__(msg)
        self.at = at
        self.repair_s = repair_s


class PredictorFailure(FaultError):
    """Predictor raised or returned non-finite scores; admission must
    degrade, never propagate this to callers."""


# --------------------------------------------------------------------------
# Fault plans
# --------------------------------------------------------------------------

KINDS = ("crash", "lane_crash", "stall", "predictor_down", "transient",
         "overflow")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``at``/``duration`` are virtual-time triggers (sim drains, windows);
    ``after_polls`` triggers on the N-th segment-boundary cancel poll of
    a real engine (wall-clock drains need a deterministic trigger that
    does not depend on timing).  ``replica < 0`` matches any replica.
    """
    kind: str
    at: float = 0.0
    duration: float = 0.0
    replica: int = -1
    factor: float = 2.0          # stall slowdown multiplier
    repair_s: float = 0.0        # crash: replica downtime
    after_polls: int = -1        # real engines: segment-poll trigger
    lane: int = -1               # lane_crash: victim lane (-1 = first busy)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """An immutable, seeded schedule of :class:`FaultSpec`.

    Build explicitly (``FaultPlan([spec, ...])``) for targeted tests, or
    with :meth:`random` for rate-based chaos: Poisson crash/transient
    arrivals with MTBF/MTTR parameters, all drawn from one
    ``np.random.default_rng(seed)`` so the plan — and therefore the whole
    chaos run — is deterministic.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = seed

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    @classmethod
    def random(cls, seed: int, horizon: float, *,
               crash_mtbf: Optional[float] = None, crash_mttr: float = 5.0,
               transient_rate: Optional[float] = None,
               stall_mtbf: Optional[float] = None, stall_s: float = 10.0,
               stall_factor: float = 2.0,
               predictor_mtbf: Optional[float] = None,
               predictor_mttr: float = 10.0,
               n_replicas: int = 1) -> "FaultPlan":
        """Rate-based plan over ``[0, horizon)``.

        ``*_mtbf`` are mean seconds between faults (None disables that
        kind); crash repair times are exponential with mean
        ``crash_mttr``.  Each fault targets a uniformly random replica.
        """
        rng = np.random.default_rng(seed)
        specs: List[FaultSpec] = []

        def poisson_times(mtbf: float) -> List[float]:
            out, t = [], 0.0
            while True:
                t += float(rng.exponential(mtbf))
                if t >= horizon:
                    return out
                out.append(t)

        if crash_mtbf:
            for t in poisson_times(crash_mtbf):
                specs.append(FaultSpec(
                    kind="crash", at=t,
                    repair_s=float(rng.exponential(crash_mttr)),
                    replica=int(rng.integers(n_replicas))))
        if transient_rate:
            for t in poisson_times(1.0 / transient_rate):
                specs.append(FaultSpec(
                    kind="transient", at=t,
                    replica=int(rng.integers(n_replicas))))
        if stall_mtbf:
            for t in poisson_times(stall_mtbf):
                specs.append(FaultSpec(
                    kind="stall", at=t, duration=stall_s,
                    factor=stall_factor,
                    replica=int(rng.integers(n_replicas))))
        if predictor_mtbf:
            for t in poisson_times(predictor_mtbf):
                specs.append(FaultSpec(kind="predictor_down", at=t,
                                       duration=predictor_mttr))
        specs.sort(key=lambda s: s.at)
        return cls(specs, seed=seed)


class FaultInjector:
    """Runtime state over a :class:`FaultPlan`: which one-shot specs have
    fired, and per-replica segment-poll counters for the wall-clock
    trigger mode.  One injector is shared by a server and its engines.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan if isinstance(plan, FaultPlan) else FaultPlan(plan)
        self.reset()

    def reset(self) -> None:
        self._fired: set = set()
        self._polls: Dict[int, int] = {}

    # ------------------------------------------------------- spec queries
    def _live(self, kind: str, replica: Optional[int] = None):
        for i, s in enumerate(self.plan.specs):
            if s.kind != kind or i in self._fired:
                continue
            if replica is not None and s.replica >= 0 \
                    and s.replica != replica:
                continue
            yield i, s

    # ---------------------------------------------- virtual-time triggers
    def transient_due(self, replica: int, now: float) -> Optional[FaultSpec]:
        """Consume one due transient-error spec (each fails one attempt)."""
        for i, s in self._live("transient", replica):
            if s.at <= now:
                self._fired.add(i)
                return s
        return None

    def crash_between(self, replica: int, t0: float,
                      t1: float) -> Optional[FaultSpec]:
        """Consume the earliest crash whose trigger falls in ``[t0, t1)``
        (a virtual-time service interval).  Poll-triggered crash specs
        (``after_polls >= 0``) are ignored here."""
        best = None
        for i, s in self._live("crash", replica):
            if s.after_polls >= 0:
                continue
            if t0 <= s.at < t1 and (best is None or s.at < best[1].at):
                best = (i, s)
        if best is None:
            return None
        self._fired.add(best[0])
        return best[1]

    def stall_factor(self, replica: int, now: float) -> float:
        """Combined straggler slowdown at ``now`` (windows never fire-out)."""
        f = 1.0
        for _, s in self._live("stall", replica):
            if s.at <= now < s.at + s.duration:
                f *= s.factor
        return f

    def predictor_down(self, now: float) -> bool:
        return any(s.at <= now < s.at + s.duration
                   for _, s in self._live("predictor_down"))

    def overflow_active(self, now: float) -> bool:
        return any(s.at <= now < s.at + s.duration
                   for _, s in self._live("overflow"))

    # --------------------------------------------- segment-poll triggers
    def poll_segment(self, replica: int) -> None:
        """Called by real engines between fused-decode segments.  Raises
        :class:`EngineCrash` when a poll-triggered crash spec fires —
        this IS the mid-generation crash, surfacing at the segment
        boundary exactly like a cancellation would."""
        c = self._polls.get(replica, 0) + 1
        self._polls[replica] = c
        for i, s in self._live("crash", replica):
            if 0 <= s.after_polls <= c:
                self._fired.add(i)
                raise EngineCrash("injected engine crash "
                                  f"(replica {replica}, poll {c})",
                                  repair_s=s.repair_s)

    def lane_crash_due(self, replica: int) -> Optional[FaultSpec]:
        """Consume a due lane crash (batched engines; poll-count
        triggered, checked once per segment)."""
        c = self._polls.get(replica, 0)
        for i, s in self._live("lane_crash", replica):
            if 0 <= s.after_polls <= c:
                self._fired.add(i)
                return s
        return None


def as_injector(plan_or_injector) -> Optional[FaultInjector]:
    """Normalize a FaultPlan / FaultInjector / spec list / None."""
    if plan_or_injector is None or isinstance(plan_or_injector,
                                              FaultInjector):
        return plan_or_injector
    return FaultInjector(plan_or_injector)


# --------------------------------------------------------------------------
# Handling: retry/backoff + circuit breaker
# --------------------------------------------------------------------------

@dataclass
class RetryPolicy:
    """Bounded retries with jittered exponential backoff.

    ``backoff(attempt)`` for attempt 0, 1, ... returns
    ``base_s * multiplier**attempt * (1 + jitter * U[0,1))`` from a
    seeded rng — deterministic for a given call sequence, but decorrelated
    across retries (no synchronized retry storms).
    """
    max_retries: int = 2
    base_s: float = 0.05
    multiplier: float = 2.0
    jitter: float = 0.2
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def backoff(self, attempt: int) -> float:
        return (self.base_s * self.multiplier ** max(0, attempt)
                * (1.0 + self.jitter * float(self._rng.random())))


class CircuitBreaker:
    """closed -> open -> half-open breaker over one replica.

    * ``closed``: requests flow; ``failure_threshold`` consecutive
      failures trip it open.
    * ``open``: requests are rejected until ``recovery_s`` has elapsed.
    * ``half_open``: exactly one probe is admitted; success closes the
      breaker, failure re-opens it (cooldown restarts).
    """

    def __init__(self, failure_threshold: int = 3, recovery_s: float = 30.0):
        self.failure_threshold = int(failure_threshold)
        self.recovery_s = float(recovery_s)
        self.state = "closed"
        self.failures = 0
        self.opened_at = 0.0
        self._probe_inflight = False

    def clone(self) -> "CircuitBreaker":
        return CircuitBreaker(self.failure_threshold, self.recovery_s)

    def would_allow(self, now: float) -> bool:
        """Side-effect-free eligibility check (placement comparisons must
        not consume the half-open probe slot)."""
        if self.state == "closed":
            return True
        if self.state == "open":
            return now - self.opened_at >= self.recovery_s
        return not self._probe_inflight

    def allow(self, now: float) -> bool:
        """May a request be sent to this replica at ``now``?  Transitions
        open -> half-open after the cooldown and COMMITS the single probe
        slot — call only when the request is actually dispatched here."""
        if self.state == "closed":
            return True
        if self.state == "open":
            if now - self.opened_at >= self.recovery_s:
                self.state = "half_open"
                self._probe_inflight = True
                return True
            return False
        # half_open: one probe at a time
        if not self._probe_inflight:
            self._probe_inflight = True
            return True
        return False

    def record_success(self, now: float = 0.0) -> None:
        self.state = "closed"
        self.failures = 0
        self._probe_inflight = False

    def record_failure(self, now: float) -> None:
        self._probe_inflight = False
        if self.state == "half_open":
            self.state = "open"
            self.opened_at = now
            return
        self.failures += 1
        if self.state == "closed" and self.failures >= self.failure_threshold:
            self.state = "open"
            self.opened_at = now
