"""Backend adapters: what the asyncio sidecar actually serves on.

The paper ships Clairvoyant as a *proxy* in front of serial LLM
backends (Ollama / llama.cpp-shaped processes).  The batch drains in
``serving/server.py`` talk to engines synchronously; the sidecar
(``serving/http_sidecar.py``) instead awaits a :class:`Backend`, one per
replica, behind a uniform async contract:

    out = await backend.generate(prompt, max_new_tokens=n,
                                 on_segment=push, cancel_cb=poll)
    # {"text", "tokens", "ttft_s", "service_s", "cancelled"}

* ``on_segment(delta: str)`` streams text out at fused-decode segment
  boundaries — the only points where tokens reach the host, hence the
  sidecar's SSE flush granularity.
* ``cancel_cb()`` is polled at the same boundaries; returning True (or a
  prior :meth:`Backend.request_cancel`) drains the request with
  ``cancelled=True`` — §3.4 semantics, now wire-triggerable by a client
  disconnect or a deadline expiry.
* injected faults surface as raises: :class:`EngineCrash` from the
  shared ``FaultInjector``'s segment polls, and
  :class:`TransientBackendError` from the HTTP adapter's connect/read
  timeouts — both feed the server's existing ``RetryPolicy`` /
  ``CircuitBreaker`` machinery unchanged.

Three adapters:

* :class:`SimTextBackend` — virtual service times from a
  ``ServiceTimeModel`` scaled by ``time_scale``, slept on the event loop
  and streamed as synthetic text.  The wire-level chaos tests and
  benchmarks run on this (hundreds of requests in seconds).
* :class:`InProcessBackend` — wraps a ``RealEngine``: the fused decode
  runs in a worker thread, segments marshal back to the loop.  The
  paper's single-binary deployment.
* :class:`HTTPBackend` — an external OpenAI-compatible HTTP backend
  (stdlib asyncio sockets only): POST /v1/chat/completions, optional SSE
  consumption, connect/read timeouts, and a ``probe()`` used for
  availability checks.  Fronts a real local-server process exactly as
  the paper describes — and doubles as the test/bench wire client
  against our own sidecar.
"""

from __future__ import annotations

import asyncio
import json
import time
from typing import Callable, Optional

from repro.data.tokenizer import approx_token_len
from repro.serving.faults import TransientBackendError
from repro.serving.service_time import ServiceTimeModel


def tokens_to_text(tokens) -> str:
    """Synthetic detokenization (the hash tokenizer is one-way): token
    ids render as ``t<id>`` words so wire responses carry *some* text
    whose word count equals the token count."""
    return " ".join(f"t{int(t)}" for t in tokens)


class Backend:
    """Async serial-backend contract (one in-flight request per replica).

    Subclasses implement :meth:`generate` and :meth:`probe`; the
    bookkeeping attributes (``busy_until``/``served``) let
    ``ClairvoyantServer`` treat a backend list as its ``engines=`` so
    routing, cancellation (``request_cancel``) and fault wiring
    (``fault_injector``) work unchanged.
    """

    def __init__(self, replica_id: int = 0):
        self.replica_id = replica_id
        self.busy_until = 0.0
        self.served = 0
        self.fault_injector = None
        #: virtual clock supplied by the sidecar (falls back to wall time
        #: from construction) — fault windows trigger against this
        self.clock: Optional[Callable[[], float]] = None
        self._t0 = time.monotonic()
        self._cancel = False

    def now(self) -> float:
        return self.clock() if self.clock is not None \
            else time.monotonic() - self._t0

    def request_cancel(self) -> None:
        """§3.4 mid-generation disconnect: observed at the next segment
        boundary."""
        self._cancel = True

    def _poll_cancel(self, cancel_cb) -> bool:
        """Shared segment-boundary poll: fault injector first (may raise
        EngineCrash — the crash lands exactly where a cancel would),
        then the engine flag, then the caller's callback."""
        if self.fault_injector is not None:
            self.fault_injector.poll_segment(self.replica_id)
        return self._cancel or (cancel_cb is not None and cancel_cb())

    async def generate(self, prompt: str, *, max_new_tokens: int = 32,
                       on_segment=None, cancel_cb=None) -> dict:
        raise NotImplementedError

    async def probe(self) -> bool:
        """Cheap availability check (half-open breaker probes, /readyz)."""
        return True

    def engine_stats(self) -> dict:
        """Uniform observability surface (mirrors the engines' method):
        whatever this backend can cheaply report about its serving state.
        Adapters that wrap a real engine delegate to it."""
        return {"replica": self.replica_id, "served": self.served}


class SimTextBackend(Backend):
    """Virtual-time backend: sleeps out a ``ServiceTimeModel`` service
    time (scaled by ``time_scale``) and streams synthetic text in
    segment-sized chunks.

    Service time is a function of the *request* (prompt tokens +
    ``max_new_tokens``), so SJF-vs-FCFS comparisons over the wire
    reproduce the virtual-time queueing results.  Injected stall windows
    stretch the sleeps; injected crashes raise out of the segment poll.
    """

    def __init__(self, model: Optional[ServiceTimeModel] = None,
                 replica_id: int = 0, *, time_scale: float = 1.0,
                 segment_tokens: int = 8):
        super().__init__(replica_id)
        self.model = model or ServiceTimeModel(prefill_tok_per_s=8000.0,
                                               decode_tok_per_s=60.0)
        self.time_scale = float(time_scale)
        self.segment_tokens = int(segment_tokens)

    async def generate(self, prompt: str, *, max_new_tokens: int = 32,
                       on_segment=None, cancel_cb=None) -> dict:
        self._cancel = False
        t0 = time.monotonic()
        ptoks = approx_token_len(prompt)
        n = max(1, int(max_new_tokens))
        full = self.model.service(ptoks, n) * self.time_scale
        prefill = (self.model.overhead_s
                   + ptoks / self.model.prefill_tok_per_s) * self.time_scale
        per_tok = max(0.0, full - prefill) / n
        await asyncio.sleep(prefill)
        ttft = time.monotonic() - t0
        tokens = [0]
        if on_segment is not None:
            on_segment(tokens_to_text(tokens))     # prefill token
        cancelled = False
        while len(tokens) < n:
            if self._poll_cancel(cancel_cb):       # may raise EngineCrash
                cancelled = True
                break
            k = min(self.segment_tokens, n - len(tokens))
            f = 1.0 if self.fault_injector is None \
                else self.fault_injector.stall_factor(self.replica_id,
                                                      self.now())
            await asyncio.sleep(per_tok * k * f)
            new = list(range(len(tokens), len(tokens) + k))
            tokens.extend(new)
            if on_segment is not None:
                on_segment(" " + tokens_to_text(new))
        self.served += not cancelled
        self._cancel = False
        return {"text": tokens_to_text(tokens), "tokens": len(tokens),
                "ttft_s": ttft, "service_s": time.monotonic() - t0,
                "cancelled": cancelled}


class InProcessBackend(Backend):
    """Wrap a ``RealEngine`` (fused on-device decode) behind the async
    contract: the blocking ``generate`` runs in a worker thread and
    segment callbacks marshal back to the event loop thread via
    ``call_soon_threadsafe`` (``on_segment`` always fires on the loop).
    """

    def __init__(self, engine, tokenizer=None):
        super().__init__(engine.replica_id)
        from repro.data.tokenizer import HashTokenizer
        self.engine = engine
        self.tokenizer = tokenizer or HashTokenizer(engine.cfg.vocab_size)

    @property
    def fault_injector(self):
        return self.engine.fault_injector

    @fault_injector.setter
    def fault_injector(self, inj):
        # Backend.__init__ assigns None before self.engine exists
        if "engine" in self.__dict__:
            self.engine.fault_injector = inj

    def request_cancel(self) -> None:
        self.engine.request_cancel()

    async def generate(self, prompt: str, *, max_new_tokens: int = 32,
                       on_segment=None, cancel_cb=None) -> dict:
        loop = asyncio.get_running_loop()
        ids = self.tokenizer.encode(prompt)
        first = [True]

        def seg(new_tokens):
            # worker thread -> loop thread; deltas join with a space
            # except the very first
            delta = tokens_to_text(new_tokens)
            if first[0]:
                first[0] = False
            else:
                delta = " " + delta
            if on_segment is not None:
                loop.call_soon_threadsafe(on_segment, delta)

        out = await asyncio.to_thread(
            self.engine.generate, ids, max_new_tokens=max_new_tokens,
            cancel_cb=cancel_cb, on_segment=seg)
        self.served = self.engine.served
        res = {"text": tokens_to_text(out["tokens"]),
               "tokens": len(out["tokens"]), "ttft_s": out["ttft_s"],
               "service_s": out["service_s"],
               "cancelled": out["cancelled"]}
        if "accept_rate" in out:          # speculative engine
            res["accept_rate"] = out["accept_rate"]
        return res

    async def probe(self) -> bool:
        return True

    def engine_stats(self) -> dict:
        return self.engine.engine_stats()


class HTTPBackend(Backend):
    """External OpenAI-compatible HTTP backend over raw asyncio sockets.

    One connection per request (``Connection: close``), explicit
    connect/read timeouts, and SSE consumption when streaming.  Network
    failures and timeouts raise :class:`TransientBackendError` so the
    server's retry/breaker machinery treats a flaky upstream exactly
    like an injected transient.
    """

    def __init__(self, host: str, port: int, *,
                 path: str = "/v1/chat/completions", model: str = "default",
                 connect_timeout_s: float = 2.0,
                 read_timeout_s: float = 60.0,
                 probe_path: str = "/healthz", replica_id: int = 0):
        super().__init__(replica_id)
        self.host = host
        self.port = int(port)
        self.path = path
        self.model = model
        self.connect_timeout_s = float(connect_timeout_s)
        self.read_timeout_s = float(read_timeout_s)
        self.probe_path = probe_path

    # ----------------------------------------------------------- low level
    async def _connect(self):
        try:
            return await asyncio.wait_for(
                asyncio.open_connection(self.host, self.port),
                self.connect_timeout_s)
        except Exception as e:
            raise TransientBackendError(
                f"connect {self.host}:{self.port} failed: "
                f"{type(e).__name__}: {e}") from e

    async def _read(self, coro):
        try:
            return await asyncio.wait_for(coro, self.read_timeout_s)
        except asyncio.TimeoutError as e:
            raise TransientBackendError(
                f"read timeout after {self.read_timeout_s}s from "
                f"{self.host}:{self.port}") from e
        except TransientBackendError:
            raise
        except Exception as e:
            raise TransientBackendError(
                f"read from {self.host}:{self.port} failed: "
                f"{type(e).__name__}: {e}") from e

    async def _request(self, method: str, path: str, body: bytes = b"",
                       headers: Optional[dict] = None):
        """Send one request, parse the status line + headers.  Returns
        (reader, writer, status:int, headers:dict)."""
        reader, writer = await self._connect()
        hdrs = {"Host": f"{self.host}:{self.port}",
                "Connection": "close",
                "Accept": "application/json, text/event-stream"}
        if body:
            hdrs["Content-Type"] = "application/json"
            hdrs["Content-Length"] = str(len(body))
        if headers:
            hdrs.update(headers)
        head = f"{method} {path} HTTP/1.1\r\n" + "".join(
            f"{k}: {v}\r\n" for k, v in hdrs.items()) + "\r\n"
        try:
            writer.write(head.encode("ascii") + body)
            await self._read(writer.drain())
            status_line = await self._read(reader.readline())
            if not status_line:
                raise TransientBackendError(
                    f"{self.host}:{self.port} closed before responding")
            parts = status_line.decode("latin-1").split(None, 2)
            status = int(parts[1])
            resp_hdrs = {}
            while True:
                line = await self._read(reader.readline())
                if line in (b"\r\n", b"\n", b""):
                    break
                k, _, v = line.decode("latin-1").partition(":")
                resp_hdrs[k.strip().lower()] = v.strip()
            return reader, writer, status, resp_hdrs
        except Exception:
            writer.close()
            raise

    @staticmethod
    def _close(writer) -> None:
        try:
            writer.close()
        except Exception:
            pass

    # ------------------------------------------------------------ generate
    async def generate(self, prompt: str, *, max_new_tokens: int = 32,
                       on_segment=None, cancel_cb=None,
                       extra: Optional[dict] = None,
                       headers: Optional[dict] = None) -> dict:
        self._cancel = False
        # stream whenever a consumer wants deltas at arrival OR might
        # cancel mid-flight: the buffered path can't observe either
        # until the upstream finishes (ROADMAP item-3 leftover)
        stream = on_segment is not None or cancel_cb is not None
        payload = {"model": self.model,
                   "messages": [{"role": "user", "content": prompt}],
                   "max_tokens": int(max_new_tokens), "stream": stream}
        if extra:
            payload.update(extra)
        body = json.dumps(payload).encode()
        t0 = time.monotonic()
        reader, writer, status, hdrs = await self._request(
            "POST", self.path, body, headers)
        try:
            ctype = hdrs.get("content-type", "")
            if stream and status == 200 and "text/event-stream" in ctype:
                return await self._consume_sse(reader, on_segment,
                                               cancel_cb, t0)
            raw = await self._read(reader.read(-1))
            if status != 200:
                # upstream refusal/failure: retryable from this side
                raise TransientBackendError(
                    f"upstream {self.host}:{self.port} returned "
                    f"{status}: {raw[:200].decode('latin-1', 'replace')}")
            doc = json.loads(raw)
            text = doc["choices"][0]["message"]["content"] or ""
            toks = doc.get("usage", {}).get("completion_tokens",
                                            len(text.split()))
            extra_info = doc.get("clairvoyant", {})
            dt = time.monotonic() - t0
            return {"text": text, "tokens": int(toks),
                    "ttft_s": extra_info.get("ttft_s", dt),
                    "service_s": dt, "cancelled": False,
                    "accept_rate": extra_info.get("accept_rate")}
        finally:
            self._close(writer)

    async def _consume_sse(self, reader, on_segment, cancel_cb,
                           t0: float) -> dict:
        """Drain an SSE stream: forward deltas, honor cancellation
        between frames (close the upstream connection — our disconnect
        IS the cancel signal to a sidecar upstream)."""
        text_parts = []
        ttft = None
        finish = None
        cancelled = False
        while True:
            if self._poll_cancel(cancel_cb):
                cancelled = True
                break
            line = await self._read(reader.readline())
            if not line:
                break                       # upstream closed
            line = line.strip()
            if not line or not line.startswith(b"data:"):
                continue
            data = line[5:].strip()
            if data == b"[DONE]":
                break
            try:
                doc = json.loads(data)
            except ValueError:
                continue
            if "error" in doc:
                raise TransientBackendError(
                    f"upstream stream error: {doc['error'].get('message')}")
            choice = doc.get("choices", [{}])[0]
            delta = choice.get("delta", {}).get("content")
            if delta:
                if ttft is None:
                    ttft = time.monotonic() - t0
                text_parts.append(delta)
                if on_segment is not None:
                    on_segment(delta)
            if choice.get("finish_reason"):
                finish = choice["finish_reason"]
        dt = time.monotonic() - t0
        text = "".join(text_parts)
        return {"text": text, "tokens": len(text.split()),
                "ttft_s": ttft if ttft is not None else dt,
                "service_s": dt,
                "cancelled": cancelled or finish == "cancelled"}

    async def probe(self) -> bool:
        """GET the probe path; any 2xx within the timeouts = available."""
        try:
            reader, writer, status, _ = await self._request(
                "GET", self.probe_path)
        except Exception:
            return False
        try:
            await self._read(reader.read(-1))
        except Exception:
            pass
        self._close(writer)
        return 200 <= status < 300
