"""The asyncio HTTP/SSE sidecar: Clairvoyant on a real wire.

A stdlib-only HTTP/1.1 server (``asyncio.start_server``) that fronts a
:class:`~repro.serving.server.ClairvoyantServer` with one async
:class:`~repro.serving.backends.Backend` per replica.  The embedded
server keeps what it is good at — predictive admission (features ->
GBDT -> p_long), routing, the SJF queues + starvation guard, the
``_finish`` terminal gate (no-lost-requests), retries/breakers and fault
stats — while the sidecar owns the wire: per-replica async dispatch
loops, SSE streaming at fused-decode segment boundaries, and the
robustness envelope the paper's proxy needs in production:

* **Deadlines** — ``X-Deadline-S`` header (or ``timeout_s`` in the
  body, or the server-wide default) bounds the whole sojourn: expiry
  before dispatch sheds (HTTP 429), expiry mid-generation stops the
  decode at the next segment boundary with terminal ``timeout``
  (HTTP 504) — the status PR 6 reserved, now wired end to end.
* **Disconnect cancellation** — a per-connection EOF watcher maps a
  dropped client onto ``ClairvoyantServer.cancel``: queued requests
  terminate ``cancelled`` immediately, mid-generation ones drain at the
  next segment boundary (§3.4), freeing the serial slot within
  ``segment_len`` tokens.
* **Backpressure** — bounded admission: server-side queue overflow
  sheds with 429 + ``Retry-After``; a wire-level in-flight cap returns
  503 + ``Retry-After`` before any work is queued.
* **Per-tenant rate limiting** — a token bucket per ``X-Tenant``
  header (which also feeds the ``fair_share`` policy's tenant field);
  over-rate requests get 429 + ``Retry-After`` without touching the
  scheduler.
* **Slow-client guards** — header/body read timeouts and bounded
  ``drain()`` waits on every write; a stalled reader is treated as a
  disconnect (its request is cancelled, the connection closed).
* **Health** — ``/healthz`` (process liveness + fault counters +
  per-replica engine stats: dead steps, speculative accept rate, paged
  pool page states) and ``/readyz`` (503 while draining, when every
  replica's breaker is open, or no backend is eligible), both
  reporting predictor degradation and per-replica breaker state;
  ``/readyz`` additionally carries the online ranking-fidelity
  snapshot.
* **Metrics** — ``/metrics`` serves Prometheus text exposition
  (``serving/observability.py``): admission/terminal counters, sojourn
  / TTFT / queue-wait / predictor-latency histograms, queue-depth and
  page-state gauges, wire-level counters, and the ranking-fidelity
  monitor.  A metrics+ranking :class:`Observability` bundle is created
  automatically when the server has none; attach one with a recorder
  to also capture Perfetto-exportable span traces.
* **Graceful drain** — ``shutdown()`` stops accepting, serves what it
  can inside ``drain_s``, then force-terminates the rest (queued ->
  ``cancelled``/"server shutdown", mid-generation -> segment-boundary
  cancel) so the no-lost-requests invariant holds across SIGTERM: every
  admitted request still gets exactly one terminal status and every
  open connection a well-formed response.

Wire shapes are OpenAI-compatible (``serving/openai_api.py``): POST
``/v1/chat/completions`` returns a ``chat.completion`` body (plus a
``clairvoyant`` extension block), or an SSE stream of
``chat.completion.chunk`` frames ending in ``data: [DONE]`` when
``"stream": true``.  Terminal statuses map to HTTP codes via
``HTTP_STATUS`` (ok 200 / shed 429 / failed 502 / timeout 504 /
cancelled 499).
"""

from __future__ import annotations

import asyncio
import json
import time
from typing import Dict, List, Optional

from repro.serving.faults import EngineCrash, TransientBackendError
from repro.serving.observability import Observability
from repro.serving.openai_api import (HTTP_STATUS, CompletionRequest,
                                      CompletionResponse,
                                      chat_completion_body, chat_chunk_body,
                                      error_body)
from repro.serving.server import ClairvoyantServer

#: Prometheus text exposition content type (format 0.0.4)
METRICS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 408: "Request Timeout",
            413: "Payload Too Large", 429: "Too Many Requests",
            499: "Client Closed Request", 500: "Internal Server Error",
            502: "Bad Gateway", 503: "Service Unavailable",
            504: "Gateway Timeout"}

_MAX_BODY = 1 << 20          # 1 MiB request-body cap


class TokenBucket:
    """Per-tenant rate limiter: ``rate`` tokens/s, burst ``burst``."""

    def __init__(self, rate: float, burst: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.t_last = None

    def allow(self, now: float):
        """Returns ``(allowed, retry_after_s)``; consumes one token when
        allowed."""
        if self.t_last is not None:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True, 0.0
        return False, max(0.0, (1.0 - self.tokens) / self.rate)


class _Waiter:
    """Per-request rendezvous between the dispatch loop and the
    connection handler: streamed deltas and the terminal response."""

    __slots__ = ("queue", "resp", "done")

    def __init__(self):
        self.queue: asyncio.Queue = asyncio.Queue()
        self.resp: Optional[CompletionResponse] = None
        self.done = asyncio.Event()

    def push_delta(self, delta: str) -> None:
        if not self.done.is_set():
            self.queue.put_nowait(("delta", delta))

    def finish(self, resp: CompletionResponse) -> None:
        self.resp = resp
        self.done.set()
        self.queue.put_nowait(("done", resp))


class Sidecar:
    """The wire wrapper.  Construct with a ``ClairvoyantServer`` whose
    ``engines`` are :class:`~repro.serving.backends.Backend` adapters
    (``deadline_mode="sojourn"`` — the wire semantics), then ``await
    start()``.
    """

    def __init__(self, server: ClairvoyantServer, *,
                 host: str = "127.0.0.1", port: int = 0,
                 model: str = "default",
                 max_inflight: int = 256,
                 tenant_rate: Optional[float] = None,
                 tenant_burst: float = 10.0,
                 header_timeout_s: float = 10.0,
                 write_timeout_s: float = 10.0,
                 drain_s: float = 30.0,
                 max_new_tokens: int = 64):
        if server.deadline_mode != "sojourn":
            raise ValueError("the sidecar requires deadline_mode='sojourn' "
                             "(in-service expiry must be enforceable)")
        self.server = server
        self.backends = list(server.engines)
        self.host = host
        self.port = port
        self.model = model
        self.max_inflight = int(max_inflight)
        self.tenant_rate = tenant_rate
        self.tenant_burst = float(tenant_burst)
        self.header_timeout_s = float(header_timeout_s)
        self.write_timeout_s = float(write_timeout_s)
        self.drain_s = float(drain_s)
        self.max_new_tokens = int(max_new_tokens)

        self._t0 = time.monotonic()
        self._srv: Optional[asyncio.base_events.Server] = None
        self._dispatchers: List[asyncio.Task] = []
        self._kick: List[asyncio.Event] = []
        self._waiters: Dict[int, _Waiter] = {}
        self._buckets: Dict[str, TokenBucket] = {}
        self._conns: set = set()
        self._stopping = False
        self._hard_stop = False
        self._stopped = asyncio.Event()
        self.wire_stats = {"connections": 0, "requests": 0,
                           "rate_limited": 0, "rejected_busy": 0,
                           "disconnects": 0, "slow_clients": 0,
                           "bad_requests": 0}
        # terminal gate hook: resolve the wire waiter whenever ANY path
        # (admission shed, drain, cancel, shutdown) emits a terminal
        self._orig_finish = server._finish
        server._finish = self._on_finish
        # backends are not RealEngines, so the server's constructor did
        # not wire the injector/clock — the sidecar owns that
        for b in self.backends:
            if server.faults is not None:
                b.fault_injector = server.faults
            b.clock = self.now
        # observability: every sidecar is scrapeable.  When the caller
        # didn't attach a bundle, build the metrics + ranking default
        # (tracing stays opt-in: attach Observability.default() with a
        # recorder before constructing the sidecar to also get spans).
        if getattr(server, "obs", None) is None:
            server.attach_observability(Observability.default(tracing=False))
        self.obs = server.obs
        if self.obs.metrics is not None:
            self._register_wire_metrics()

    # ------------------------------------------------------------ plumbing
    def now(self) -> float:
        """The sidecar's virtual clock IS wall time since construction
        (arrivals, deadlines and fault windows share this axis)."""
        return time.monotonic() - self._t0

    def _register_wire_metrics(self) -> None:
        """Scrape-time export of the wire-level stats the sidecar keeps."""
        reg = self.obs.metrics
        c_wire = reg.counter("clairvoyant_wire_total",
                             "Wire-level events by kind")
        g_winf = reg.gauge("clairvoyant_wire_inflight",
                           "Open wire requests (pre-terminal waiters)")
        g_conn = reg.gauge("clairvoyant_wire_connections",
                           "Open TCP connections")

        def collect():
            for k, v in self.wire_stats.items():
                c_wire.set_total(v, kind=k)
            g_winf.set(len(self._waiters))
            g_conn.set(len(self._conns))

        reg.add_collector(collect)

    def _on_finish(self, resp: CompletionResponse) -> None:
        self._orig_finish(resp)
        w = self._waiters.get(resp.request_id)
        if w is not None:
            w.finish(resp)

    @property
    def address(self) -> str:
        return f"http://{self.host}:{self.port}"

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        self._srv = await asyncio.start_server(self._handle_conn,
                                               self.host, self.port)
        if self.port == 0:
            self.port = self._srv.sockets[0].getsockname()[1]
        for rep, backend in zip(self.server.router.replicas, self.backends):
            self._kick.append(asyncio.Event())
            self._dispatchers.append(asyncio.create_task(
                self._dispatch_loop(rep, backend)))

    async def shutdown(self, drain_s: Optional[float] = None) -> None:
        """Graceful drain: stop accepting, serve in-flight work inside
        the budget, then force-terminate what remains — every admitted
        request still exits through the terminal gate."""
        self._stopping = True
        if self._srv is not None:
            self._srv.close()
            await self._srv.wait_closed()
        budget = self.drain_s if drain_s is None else float(drain_s)
        deadline = time.monotonic() + budget
        while time.monotonic() < deadline:
            if not self.server._decoding and not any(
                    rep.queue.live() for rep in self.server.router.replicas):
                break
            await asyncio.sleep(0.005)
        # budget exhausted (or already drained): cancel mid-generation
        # work at the next segment boundary, terminate everything queued
        self._hard_stop = True
        for b in self.backends:
            b.request_cancel()
        for rep in self.server.router.replicas:
            for req in list(rep.queue.live()):
                rep.queue.remove(req.req_id)
                self.server.router.release(rep.replica_id, req)
                self.server._finish(CompletionResponse(
                    request_id=req.req_id, text="", tokens_generated=0,
                    queue_wait_s=max(0.0, self.now() - req.arrival),
                    service_s=0.0, replica=rep.replica_id,
                    p_long=req.p_long, klass=req.klass,
                    status="cancelled", error="server shutdown",
                    retries=req.meta.get("fault_retries", 0),
                    degraded=bool(req.meta.get("degraded"))))
        self._stopped.set()
        for ev in self._kick:
            ev.set()
        if self._dispatchers:
            await asyncio.gather(*self._dispatchers, return_exceptions=True)
        # connection handlers finish their final writes; then force-close
        for _ in range(100):                 # <=1 s of grace
            if not self._conns:
                break
            await asyncio.sleep(0.01)
        for w in list(self._conns):
            try:
                w.close()
            except Exception:
                pass
        self._conns.clear()

    # --------------------------------------------------------- dispatching
    async def _dispatch_loop(self, rep, backend) -> None:
        """One replica's serial serve loop: pop (starvation guard applied
        per decision, like the virtual-time drains) -> serve -> repeat.
        Exits when shutdown has terminated the queue."""
        kick = self._kick[rep.replica_id]
        while True:
            req = rep.queue.pop(now=self.now())
            if req is None:
                if self._stopped.is_set():
                    return
                kick.clear()
                try:
                    await asyncio.wait_for(kick.wait(), timeout=0.05)
                except asyncio.TimeoutError:
                    pass
                continue
            try:
                await self._serve_one(rep, backend, req)
            except Exception as e:           # defensive: never lose a pop
                if req.req_id not in self.server._terminal:
                    self.server._retry_or_fail(rep, req, self.now(), e,
                                               charge_backoff=False)

    async def _serve_one(self, rep, backend, req) -> None:
        srv = self.server
        t = max(self.now(), req.arrival)
        if srv._maybe_shed(rep, req, t):
            return                           # pre-dispatch expiry: shed
        # injected transient at dispatch (same point as the drains)
        if srv.faults is not None:
            spec = srv.faults.transient_due(rep.replica_id, t)
            if spec is not None:
                nb = srv._retry_or_fail(rep, req, t,
                                        TransientBackendError(
                                            "injected transient backend "
                                            "error"))
                await asyncio.sleep(max(0.0, nb - t))    # serial backoff
                return
        if req.start is None:
            req.start = t
        rid = req.req_id
        creq = srv._inflight.get(rid)
        n_new = max(1, min(creq.max_tokens if creq else self.max_new_tokens,
                           req.meta.get("output_tokens",
                                        self.max_new_tokens)))
        dl = srv._deadline_of(req)
        deadline_hit = []

        def cancel_cb() -> bool:
            if self._hard_stop:
                return True
            if dl is not None and (self.now() - req.arrival) > dl:
                deadline_hit.append(True)
                return True
            return False

        w = self._waiters.get(rid)
        on_segment = w.push_delta if w is not None and creq is not None \
            and creq.stream else None
        rec = self.obs.recorder
        seg_marks: List[float] = []
        if rec is not None:
            # wrap the delta pusher so every segment boundary leaves a
            # timestamp mark (streamed or not) for decode_segment spans
            _push = on_segment

            def on_segment(delta, _p=_push, _m=seg_marks):
                _m.append(self.now())
                if _p is not None:
                    _p(delta)
        srv._decoding[rep.replica_id] = rid
        try:
            out = await backend.generate(req.prompt, max_new_tokens=n_new,
                                         on_segment=on_segment,
                                         cancel_cb=cancel_cb)
        except Exception as e:
            t_err = self.now()
            if isinstance(e, EngineCrash) and e.repair_s > 0:
                await asyncio.sleep(e.repair_s)          # replica down
                t_err = self.now()
            nb = srv._retry_or_fail(rep, req, t_err, e,
                                    charge_backoff=not isinstance(
                                        e, EngineCrash))
            await asyncio.sleep(max(0.0, nb - t_err))    # serial backoff
            return
        finally:
            srv._decoding.pop(rep.replica_id, None)
        t_end = self.now()
        backend.busy_until = t_end
        retries = req.meta.get("fault_retries", 0)
        common = dict(request_id=rid, tokens_generated=out["tokens"],
                      queue_wait_s=req.start - req.arrival,
                      service_s=out["service_s"] if retries == 0
                      else t_end - req.start,
                      ttft_s=req.start - req.arrival + out["ttft_s"],
                      promoted=req.promoted, replica=rep.replica_id,
                      p_long=req.p_long, klass=req.klass, retries=retries,
                      degraded=bool(req.meta.get("degraded")),
                      accept_rate=out.get("accept_rate"))
        req.finish = t_end
        if rec is not None:
            # spans land before _finish so the root "request" span (the
            # observe_terminal hook) stretches over them
            trk = f"replica{rep.replica_id}"
            t_gen0 = max(t, t_end - out["service_s"])
            t_pref = min(t_gen0 + max(out["ttft_s"], 0.0), t_end)
            rec.span("queue_wait", rid, req.arrival, t_gen0,
                     track=f"req{rid}")
            rec.span("prefill", rid, t_gen0, t_pref, track=trk)
            rec.span("decode", rid, t_pref, t_end, track=trk)
            edges = [t_pref]
            for m in seg_marks:           # measured segment boundaries
                if t_pref < m < t_end:
                    edges.append(max(m, edges[-1]))
            edges.append(t_end)
            for i in range(len(edges) - 1):
                if edges[i + 1] > edges[i]:
                    rec.span("decode_segment", rid, edges[i],
                             edges[i + 1], track=trk)
        if out["cancelled"]:
            if rid in srv._disconnected:
                srv._disconnected.discard(rid)
                srv._finish(CompletionResponse(
                    text=out["text"], status="cancelled",
                    error="client disconnect (mid-generation)", **common))
            elif deadline_hit:
                srv.fault_stats["timeouts"] += 1
                srv.router.release(rep.replica_id, req)
                srv._finish(CompletionResponse(
                    text=out["text"], status="timeout",
                    error="deadline expired in service", **common))
            else:                            # shutdown hard-stop
                srv.router.release(rep.replica_id, req)
                srv._finish(CompletionResponse(
                    text=out["text"], status="cancelled",
                    error="server shutdown", **common))
            return
        srv.router.on_dispatch(rep.replica_id, req, t_end,
                               service_estimate=out["service_s"])
        srv.router.record_success(rep.replica_id, t_end)
        srv._finish(CompletionResponse(text=out["text"], status="ok",
                                       **common))

    # ------------------------------------------------------------- the wire
    async def _handle_conn(self, reader, writer) -> None:
        self.wire_stats["connections"] += 1
        self._conns.add(writer)
        try:
            await self._handle_one(reader, writer)
        except (ConnectionError, asyncio.TimeoutError):
            pass
        except Exception:
            try:
                await self._respond(writer, 500,
                                    error_body("failed", "internal error"))
            except Exception:
                pass
        finally:
            self._conns.discard(writer)
            try:
                writer.close()
            except Exception:
                pass

    async def _handle_one(self, reader, writer) -> None:
        try:
            line = await asyncio.wait_for(reader.readline(),
                                          self.header_timeout_s)
        except asyncio.TimeoutError:
            return
        if not line:
            return
        try:
            method, path, _ = line.decode("latin-1").split(None, 2)
        except ValueError:
            self.wire_stats["bad_requests"] += 1
            await self._respond(writer, 400,
                                error_body("failed", "malformed request"))
            return
        headers = {}
        while True:
            h = await asyncio.wait_for(reader.readline(),
                                       self.header_timeout_s)
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        path = path.split("?", 1)[0]
        if method == "GET" and path == "/healthz":
            await self._respond(writer, 200, self._health_doc())
            return
        if method == "GET" and path == "/readyz":
            ready, doc = self._ready_doc()
            await self._respond(writer, 200 if ready else 503, doc)
            return
        if method == "GET" and path == "/metrics":
            await self._respond_text(writer, 200,
                                     self.obs.render_metrics(),
                                     METRICS_CONTENT_TYPE)
            return
        if path != "/v1/chat/completions":
            await self._respond(writer, 404,
                                error_body("failed", f"no route {path}"))
            return
        if method != "POST":
            await self._respond(writer, 405,
                                error_body("failed", "POST required"))
            return
        await self._handle_chat(reader, writer, headers)

    async def _handle_chat(self, reader, writer, headers) -> None:
        srv = self.server
        self.wire_stats["requests"] += 1
        if self._stopping:
            await self._respond(writer, 503,
                                error_body("shed", "server draining"),
                                extra={"Retry-After": "1"})
            return
        if len(self._waiters) >= self.max_inflight:
            self.wire_stats["rejected_busy"] += 1
            await self._respond(writer, 503,
                                error_body("shed", "too many in-flight "
                                           "requests"),
                                extra={"Retry-After": "1"})
            return
        try:
            clen = int(headers.get("content-length", "0"))
            if clen > _MAX_BODY:
                await self._respond(writer, 413,
                                    error_body("failed", "body too large"))
                return
            raw = await asyncio.wait_for(reader.readexactly(clen),
                                         self.header_timeout_s)
            body = json.loads(raw) if raw else {}
            prompt = body.get("prompt")
            if prompt is None:
                msgs = body.get("messages") or []
                prompt = msgs[-1]["content"] if msgs else None
            if not prompt or not isinstance(prompt, str):
                raise ValueError("no prompt/messages content")
        except (asyncio.TimeoutError, asyncio.IncompleteReadError):
            self.wire_stats["bad_requests"] += 1
            await self._respond(writer, 408,
                                error_body("failed", "body read timeout"))
            return
        except Exception as e:
            self.wire_stats["bad_requests"] += 1
            await self._respond(writer, 400,
                                error_body("failed", f"bad request: {e}"))
            return
        tenant = headers.get("x-tenant") or body.get("user") or "default"
        # per-tenant token bucket: refusal happens BEFORE the scheduler
        # sees the request (rate-limited work is never admitted)
        if self.tenant_rate is not None:
            bucket = self._buckets.get(tenant)
            if bucket is None:
                bucket = self._buckets[tenant] = TokenBucket(
                    self.tenant_rate, self.tenant_burst)
            okay, after = bucket.allow(self.now())
            if not okay:
                self.wire_stats["rate_limited"] += 1
                await self._respond(
                    writer, 429,
                    error_body("shed", f"tenant {tenant!r} over rate "
                               f"limit"),
                    extra={"Retry-After": f"{after:.3f}"})
                return
        stream = bool(body.get("stream"))
        dl = headers.get("x-deadline-s", body.get("timeout_s"))
        try:
            dl = None if dl is None else float(dl)
        except (TypeError, ValueError):
            await self._respond(writer, 400,
                                error_body("failed", "bad deadline"))
            return
        # pre-register the waiter so an admission-time shed (overflow)
        # resolves it synchronously inside submit()
        rid = srv.allocate_id()
        w = _Waiter()
        self._waiters[rid] = w
        creq = CompletionRequest(
            prompt=prompt, max_tokens=int(body.get("max_tokens", 1024)),
            model=body.get("model", self.model), tenant=tenant,
            stream=stream, request_id=rid)
        otoks = body.get("output_tokens")      # test/bench oracle override
        try:
            replica = srv.submit(
                creq, arrival=self.now(),
                true_output_tokens=None if otoks is None else int(otoks),
                klass=body.get("klass", ""), deadline_s=dl)
        except RuntimeError as e:              # e.g. every breaker open
            self._waiters.pop(rid, None)
            await self._respond(writer, 503,
                                error_body("shed", str(e), request_id=rid),
                                extra={"Retry-After": "1"})
            return
        if replica >= 0:
            self._kick[replica].set()
        watcher = asyncio.create_task(self._watch_disconnect(reader, rid))
        try:
            if stream:
                await self._stream_response(writer, rid, w)
            else:
                await w.done.wait()
                resp = w.resp
                await self._respond(
                    writer, HTTP_STATUS[resp.status],
                    chat_completion_body(resp, self.model,
                                         extra=self._clairvoyant_extra())
                    if resp.status == "ok"
                    else error_body(resp.status, resp.error or resp.status,
                                    request_id=rid),
                    extra={"Retry-After": "1"}
                    if resp.status == "shed" else None)
        finally:
            watcher.cancel()
            try:
                await watcher
            except (asyncio.CancelledError, Exception):
                pass
            self._waiters.pop(rid, None)

    async def _stream_response(self, writer, rid: int, w: _Waiter) -> None:
        """SSE writer: chunk frames at segment boundaries, a final frame
        carrying ``finish_reason`` (the terminal status), an error frame
        for non-ok terminals, then ``[DONE]``.  A pre-first-delta
        failure degrades to a plain JSON error response."""
        started = False
        while True:
            kind, payload = await w.queue.get()
            if kind == "delta":
                if not started:
                    head = ("HTTP/1.1 200 OK\r\n"
                            "Content-Type: text/event-stream\r\n"
                            "Cache-Control: no-cache\r\n"
                            "Connection: close\r\n\r\n")
                    writer.write(head.encode("ascii"))
                    started = True
                frame = "data: " + json.dumps(chat_chunk_body(
                    rid, self.model, payload)) + "\n\n"
                writer.write(frame.encode())
                await self._guarded_drain(writer, rid)
                continue
            resp: CompletionResponse = payload
            if not started:
                # nothing streamed yet: plain JSON is kinder to clients
                await self._respond(
                    writer, HTTP_STATUS[resp.status],
                    chat_completion_body(resp, self.model,
                                         extra=self._clairvoyant_extra())
                    if resp.status == "ok"
                    else error_body(resp.status, resp.error or resp.status,
                                    request_id=rid),
                    extra={"Retry-After": "1"}
                    if resp.status == "shed" else None)
                return
            finish = "stop" if resp.status == "ok" else resp.status
            frames = ["data: " + json.dumps(chat_chunk_body(
                rid, self.model, "", finish_reason=finish)) + "\n\n"]
            if resp.status != "ok":
                frames.append("data: " + json.dumps(error_body(
                    resp.status, resp.error or resp.status,
                    request_id=rid)) + "\n\n")
            frames.append("data: [DONE]\n\n")
            writer.write("".join(frames).encode())
            await self._guarded_drain(writer, rid, final=True)
            return

    async def _guarded_drain(self, writer, rid: int,
                             final: bool = False) -> None:
        """Bounded write: a client that cannot take bytes within
        ``write_timeout_s`` is a stalled reader — treat as disconnect
        (cancel the request) instead of wedging the connection handler."""
        try:
            await asyncio.wait_for(writer.drain(), self.write_timeout_s)
        except (asyncio.TimeoutError, ConnectionError):
            if not final:
                self.wire_stats["slow_clients"] += 1
                self._client_gone(rid)
            raise ConnectionError("slow or disconnected client")

    async def _watch_disconnect(self, reader, rid: int) -> None:
        """EOF watcher: the client closing (or resetting) its half of
        the connection cancels the request — queued or mid-generation."""
        try:
            await reader.read(1)             # EOF (or stray bytes) = gone
        except asyncio.CancelledError:
            raise
        except Exception:
            pass
        self._client_gone(rid)

    def _client_gone(self, rid: int) -> None:
        if rid in self.server._terminal:
            return
        self.wire_stats["disconnects"] += 1
        self.server.cancel(rid)

    # --------------------------------------------------------------- health
    def _clairvoyant_extra(self) -> Optional[dict]:
        """Extra keys for the response ``clairvoyant`` block: the online
        ranking-fidelity snapshot (cheap — cached between refreshes)."""
        mon = self.obs.ranking
        return {"ranking": mon.snapshot_cached()} if mon is not None \
            else None

    def _health_doc(self) -> dict:
        srv = self.server
        return {"status": "ok", "stopping": self._stopping,
                "degraded": srv.degraded,
                "inflight": len(self._waiters),
                "fault_stats": dict(srv.fault_stats),
                "wire_stats": dict(self.wire_stats),
                # per-replica engine detail: dead_steps, speculative
                # accept_rate, paged-pool page states, ... (whatever the
                # backend can report)
                "engines": [b.engine_stats() for b in self.backends
                            if hasattr(b, "engine_stats")],
                "replicas": self._replica_docs()}

    def _ready_doc(self):
        srv = self.server
        now = self.now()
        eligible = [r for r in srv.router.replicas
                    if srv.router.eligible(r.replica_id, now)]
        ready = not self._stopping and bool(eligible)
        mon = self.obs.ranking
        doc = {"ready": ready, "stopping": self._stopping,
               "degraded": srv.degraded,
               "eligible_replicas": len(eligible),
               "ranking": mon.snapshot_cached() if mon is not None
               else None,
               "replicas": self._replica_docs()}
        return ready, doc

    def _replica_docs(self) -> list:
        return [{"id": r.replica_id, "healthy": r.healthy,
                 "breaker": r.breaker.state if r.breaker is not None
                 else "none",
                 "queued": len(r.queue)}
                for r in self.server.router.replicas]

    async def _respond(self, writer, status: int, doc: dict,
                       extra: Optional[dict] = None) -> None:
        body = json.dumps(doc).encode()
        hdrs = {"Content-Type": "application/json",
                "Content-Length": str(len(body)),
                "Connection": "close"}
        if extra:
            hdrs.update(extra)
        head = f"HTTP/1.1 {status} {_REASONS.get(status, 'Status')}\r\n" \
            + "".join(f"{k}: {v}\r\n" for k, v in hdrs.items()) + "\r\n"
        writer.write(head.encode("ascii") + body)
        try:
            await asyncio.wait_for(writer.drain(), self.write_timeout_s)
        except (asyncio.TimeoutError, ConnectionError):
            pass

    async def _respond_text(self, writer, status: int, text: str,
                            content_type: str = "text/plain") -> None:
        """Plain-text response (the /metrics exposition body)."""
        body = text.encode("utf-8")
        head = (f"HTTP/1.1 {status} {_REASONS.get(status, 'Status')}\r\n"
                f"Content-Type: {content_type}\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n")
        writer.write(head.encode("ascii") + body)
        try:
            await asyncio.wait_for(writer.drain(), self.write_timeout_s)
        except (asyncio.TimeoutError, ConnectionError):
            pass
