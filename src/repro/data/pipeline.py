"""Dataset filtering / splitting pipeline (paper §4.2 "data filtering recipe"
and Table 3 splits).

Mirrors data/pipeline/featurize.py from the paper's artifact: first-turn
extraction and language filtering are structural no-ops for the synthetic
corpora (we generate single-turn English), but the hooks are kept so a real
corpus drops in unchanged.  Class boundaries, stratified balancing, and the
80/10/10 stratified split match the paper exactly (seed 42).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.features import extract_batch
from repro.core.ranking import class_labels
from repro.data.corpus import Dataset, sample_dataset

# Table 3: per-class split sizes for each trained model
MODEL_SPLITS = {
    "A": {"dataset": "sharegpt", "train": 1600, "val": 200, "test": 200},
    "B": {"dataset": "lmsys", "train": 1600, "val": 200, "test": 200},
    "C": {"dataset": "oasst1", "train": 220, "val": 28, "test": 28},
}


@dataclass
class Split:
    X: np.ndarray          # (N, 19) features
    y: np.ndarray          # (N,) class labels
    lengths: np.ndarray    # (N,) true response tokens
    prompts: list

    def __len__(self):
        return len(self.y)


@dataclass
class DataSplits:
    train: Split
    val: Split
    test: Split


def featurize(ds: Dataset) -> Tuple[np.ndarray, np.ndarray]:
    """(features, labels) — step (3)+(4) of the recipe."""
    X = extract_batch(ds.prompts)
    y = class_labels(ds.lengths)
    return X, y


def stratified_split(ds: Dataset, per_class: Dict[str, int],
                     seed: int = 42) -> DataSplits:
    """Balanced per-class train/val/test split (Table 3)."""
    rng = np.random.default_rng(seed)
    X, y = featurize(ds)
    idx_by_class = [np.where(y == c)[0] for c in range(3)]
    parts: Dict[str, list] = {"train": [], "val": [], "test": []}
    for c, idx in enumerate(idx_by_class):
        idx = idx.copy()
        rng.shuffle(idx)
        need = per_class["train"] + per_class["val"] + per_class["test"]
        if len(idx) < need:
            raise ValueError(
                f"class {c}: need {need} examples, corpus has {len(idx)} — "
                "Long-class starvation (the paper's Table 2 finding)")
        o = 0
        for part in ("train", "val", "test"):
            k = per_class[part]
            parts[part].append(idx[o:o + k])
            o += k

    def mk(name):
        sel = np.concatenate(parts[name])
        rng.shuffle(sel)
        return Split(X=X[sel], y=y[sel], lengths=ds.lengths[sel],
                     prompts=[ds.prompts[i] for i in sel])

    return DataSplits(train=mk("train"), val=mk("val"), test=mk("test"))


def load_model_splits(model: str, seed: int = 42,
                      oversample: int = 4) -> DataSplits:
    """Build the Table 3 splits for Model A/B/C from the synthetic profiles.

    ``oversample`` draws a larger raw pool so every class has enough examples
    to fill its balanced quota (the generator is unbalanced like the source)."""
    spec = MODEL_SPLITS[model]
    need = (spec["train"] + spec["val"] + spec["test"]) * 3
    from repro.data.corpus import PROFILES
    p_min = PROFILES[spec["dataset"]].class_probs.min()
    n_raw = int(need / max(p_min, 1e-6) * 1.2) + 500
    ds = sample_dataset(spec["dataset"], n=n_raw, seed=seed)
    per_class = {k: spec[k] for k in ("train", "val", "test")}
    return stratified_split(ds, per_class, seed=seed)


def heldout_eval_set(dataset: str, n: int = 600, seed: int = 7) -> Split:
    """Unbalanced-source, class-balanced eval set of n examples (Table 6
    cross-distribution cells use n=600)."""
    ds = sample_dataset(dataset, n=max(3 * n, 6000), seed=seed)
    X, y = featurize(ds)
    rng = np.random.default_rng(seed + 1)
    sel = []
    per = n // 3
    for c in range(3):
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        take = idx[:per]
        if len(take) < per:  # degenerate profiles (alpaca/cnn): take what exists
            pass
        sel.append(take)
    sel = np.concatenate(sel)
    rng.shuffle(sel)
    return Split(X=X[sel], y=y[sel], lengths=ds.lengths[sel],
                 prompts=[ds.prompts[i] for i in sel])
