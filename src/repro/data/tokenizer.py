"""Tokenization substrate.

Two pieces:
* ``approx_token_len`` — the paper's serving-side proxy (len(prompt)//4,
  §3.2); divergence for code/multilingual inputs is a documented limitation.
* ``HashTokenizer`` — a deterministic hashed word-piece tokenizer for the LM
  training pipeline (offline container: no BPE vocab files).  Maps text to
  ids in [0, vocab) via split + rolling hash, reversible enough for language-
  model training demos and fully deterministic across processes (critical for
  the data-parallel loader: every host must agree on the stream).
"""

from __future__ import annotations

import numpy as np


def approx_token_len(text: str) -> int:
    return len(text) // 4


class HashTokenizer:
    def __init__(self, vocab_size: int, seed: int = 1234567891):
        self.vocab_size = vocab_size
        self.seed = seed

    def encode(self, text: str) -> np.ndarray:
        ids = []
        for word in text.split():
            h = self.seed
            for ch in word:
                h = (h * 1000003 ^ ord(ch)) & 0x7FFFFFFF
            ids.append(h % self.vocab_size)
        return np.asarray(ids, np.int32)

    def encode_batch(self, texts, pad_to: int) -> np.ndarray:
        out = np.zeros((len(texts), pad_to), np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t)[:pad_to]
            out[i, : len(ids)] = ids
        return out
