"""LM training data pipeline: deterministic synthetic token streams with
sharded, prefetching batch iteration.

The stream is an order-k Markov chain over the vocabulary seeded per shard —
learnable structure (a real LM's loss visibly decreases) without any corpus
on disk.  The loader yields host-local shards of the global batch given
(host_index, host_count), the same contract a 1000-node data pipeline needs:
every host computes its slice of the same deterministic stream, no
coordination traffic.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 1
    branching: int = 8      # candidate successors per state (lower = easier)


class SyntheticLMStream:
    """Deterministic markov token stream, shardable by (host, n_hosts)."""

    def __init__(self, cfg: LMDataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        rng = np.random.default_rng(cfg.seed)
        # successor table: state -> branching candidate next tokens
        self._succ = rng.integers(
            0, cfg.vocab_size, (cfg.vocab_size, cfg.branching), dtype=np.int32)

    def batch(self, step: int) -> dict:
        """The host-local slice of global batch ``step`` (pure function of
        (seed, step, host) — restart/elastic-resume safe)."""
        cfg = self.cfg
        rows = np.arange(self.local_batch) + self.host_index * self.local_batch
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) % (2 ** 63))
        starts = rng.integers(0, cfg.vocab_size, cfg.global_batch)
        picks = rng.integers(0, cfg.branching,
                             (cfg.global_batch, cfg.seq_len + 1))
        toks = np.zeros((self.local_batch, cfg.seq_len + 1), np.int32)
        cur = starts[rows].astype(np.int32)
        for t in range(cfg.seq_len + 1):
            toks[:, t] = cur
            cur = self._succ[cur, picks[rows, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch (compute/IO overlap on the host side)."""

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0,
                 depth: int = 2):
        self.stream = stream
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.stream.batch(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
