"""Synthetic corpus profiles for the seven evaluated datasets (paper §4.2).

The container is offline, so the public datasets are replaced by generative
profiles that reproduce each dataset's *published statistics* (Table 2
Long-class rates) and its *lexical-signal structure*:

* class mix — e.g. Alpaca's GPT-imposed brevity constraint is modelled
  directly: Long probability 8e-5 (4 in 52,002), which reproduces the paper's
  degenerate-training finding structurally, not just numerically;
* signal strength — per-profile noise on the feature/class coupling sets the
  achievable ranking accuracy (LMSYS-like is clean -> ~95%, ShareGPT-like is
  mixed -> ~76%, OASST1-like is small+noisy -> ~62%);
* domain shift — verb/keyword semantics differ across profiles (in the
  lmsys-like profile code prompts signal Long; in sharegpt-like they skew
  Short), which is what produces the paper's 52-66% cross-distribution band.

Generation order is class -> lexical features -> prompt text -> response
length, so the learnable signal is exactly the lexical features the paper
uses.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.features import INSTRUCTION_VERBS

SHORT, MEDIUM, LONG = 0, 1, 2
CLASS_NAMES = ("short", "medium", "long")

_TOPICS = (
    "the french revolution", "binary search trees", "photosynthesis",
    "the stock market", "quantum entanglement", "sourdough bread",
    "the roman empire", "neural networks", "climate change", "chess openings",
    "the water cycle", "renaissance art", "black holes", "supply chains",
    "genetic drift", "jazz harmony", "plate tectonics", "game theory",
    "the immune system", "medieval castles", "rust ownership",
    "distributed systems", "the krebs cycle", "haiku poetry",
    "orbital mechanics", "tax law", "coffee roasting", "graph colouring",
    "marine ecosystems", "the printing press",
)

_FILLERS = (
    "please", "kindly", "also", "specifically", "ideally", "overall",
    "for context", "as an expert", "for a beginner", "for my homework",
    "for work", "in simple terms", "with examples", "from first principles",
    "carefully", "quickly", "roughly", "accurately",
)

_CLAUSES = (
    "because i need it for a project", "which i find confusing",
    "that my teacher mentioned", "since i am new to this",
    "although i read the wiki", "when i tried it before",
    "if that makes sense", "where it applies in practice",
    "because the documentation is unclear", "which keeps coming up at work",
)

_CODE_SNIPPETS = (
    "a python function", "a javascript class", "an sql query",
    "a sorting algorithm", "a regex pattern", "an api client",
    "a c++ program", "a shell script",
)

_FORMAT_ASKS = (
    "as a table", "as a json object", "as a markdown list",
    "as a csv file", "as a numbered list", "in yaml format",
)

_SHORT_CONSTRAINTS = ("briefly", "in one sentence", "be concise", "tl;dr",
                      "short answer only")
_LONG_CONSTRAINTS = ("in detail", "comprehensive", "step by step",
                     "at length", "as an essay with paragraphs")

# verb indices: what write explain summarize how list implement compare
#               describe generate why define  (+other)
_V = {v: i for i, v in enumerate(INSTRUCTION_VERBS)}


@dataclass
class LexStyle:
    """Feature/class coupling for one dataset profile.

    Two noise knobs shape the accuracy structure: ``noise_adjacent`` leaks
    features to the *neighbouring* class (blurs Short/Medium and Medium/Long
    boundaries -> classification accuracy drops, ranking survives — the
    paper's +21-29 pp ranking-over-classification gap), while
    ``noise_uniform`` leaks to a random class (degrades ranking itself).
    """
    # P(verb-bucket | class): rows = class, entries = (verb_idx, weight)
    verb_affinity: Dict[int, Tuple[Tuple[int, float], ...]]
    verb_strength: float                         # 1 = fully class-coupled, 0 = noise
    code_prob: Tuple[float, float, float]        # P(code keywords | class)
    constraint_prob: Tuple[float, float, float]  # P(length constraint | class)
    question_prob: Tuple[float, float, float]
    format_prob: Tuple[float, float, float]
    clause_rate: Tuple[float, float, float]      # Poisson rate per class
    words_mean: Tuple[float, float, float]       # prompt length (words)
    words_std: Tuple[float, float, float]
    noise_adjacent: float
    noise_uniform: float


@dataclass
class CorpusProfile:
    name: str
    published_total: int
    published_counts: Tuple[int, int, int]   # (short, medium, long) — Table 2
    style: LexStyle
    response_long_mean: float = 1400.0       # mean Long response tokens

    @property
    def class_probs(self) -> np.ndarray:
        c = np.asarray(self.published_counts, float)
        return c / c.sum()


_CANONICAL_VERBS = {   # sharegpt-reference semantics
    SHORT: (("what", 3.0), ("define", 2.0), ("why", 1.0), ("how", 0.5)),
    MEDIUM: (("explain", 2.0), ("summarize", 2.0), ("compare", 1.0),
             ("list", 1.0), ("describe", 1.0)),
    LONG: (("write", 3.0), ("generate", 2.0), ("implement", 1.5),
           ("describe", 0.5)),
}

_LMSYS_VERBS = {       # shifted semantics: 'write X' is a terse request here;
    SHORT: (("write", 2.0), ("what", 2.0), ("define", 1.5), ("list", 1.0)),
    MEDIUM: (("summarize", 2.0), ("compare", 1.5), ("why", 1.0),
             ("generate", 1.0)),
    LONG: (("explain", 2.5), ("how", 2.0), ("describe", 1.5),
           ("implement", 0.5)),
}

_DOLLY_VERBS = {       # mild shift from canonical
    SHORT: (("what", 3.0), ("define", 2.0), ("list", 1.0), ("how", 0.5)),
    MEDIUM: (("explain", 2.0), ("summarize", 2.0), ("why", 1.0),
             ("describe", 1.0)),
    LONG: (("write", 3.0), ("generate", 2.0), ("explain", 1.0)),
}


def _verbs(table):
    return {k: tuple((_V[name], w) for name, w in v) for k, v in table.items()}


def _mk_style(verbs, verb_strength, code_prob, noise_adjacent, noise_uniform,
              words_mean=(9.0, 12.0, 15.0), words_std=(6.0, 9.0, 14.0),
              question_prob=(0.75, 0.40, 0.10),
              format_prob=(0.12, 0.10, 0.08),
              clause_rate=(0.2, 1.0, 2.2),
              constraint_prob=(0.30, 0.06, 0.40)) -> LexStyle:
    return LexStyle(
        verb_affinity=_verbs(verbs),
        verb_strength=verb_strength,
        code_prob=code_prob,
        constraint_prob=constraint_prob,   # short OR long constraints
        question_prob=question_prob,
        format_prob=format_prob,
        clause_rate=clause_rate,
        words_mean=words_mean,
        words_std=words_std,
        noise_adjacent=noise_adjacent,
        noise_uniform=noise_uniform,
    )


PROFILES: Dict[str, CorpusProfile] = {
    # natural conversation logs — viable training sources.
    # Code/format keywords skew SHORT in all profiles (why the paper's
    # keyword heuristic lands below random), with per-profile strength.
    "sharegpt": CorpusProfile(
        name="sharegpt", published_total=48312,
        published_counts=(27000, 17000, 7800),
        style=_mk_style(_CANONICAL_VERBS, 0.9, (0.40, 0.20, 0.08),
                        noise_adjacent=0.40, noise_uniform=0.10)),
    "lmsys": CorpusProfile(
        name="lmsys", published_total=876412,
        published_counts=(520000, 360000, 120000),
        style=_mk_style(_LMSYS_VERBS, 1.0, (0.85, 0.30, 0.02),
                        noise_adjacent=0.28, noise_uniform=0.01,
                        format_prob=(0.30, 0.10, 0.02),
                        constraint_prob=(0.40, 0.06, 0.55),
                        clause_rate=(0.15, 1.2, 3.0))),
    "oasst1": CorpusProfile(
        name="oasst1", published_total=8792,
        published_counts=(7300, 940, 551),
        style=_mk_style(_CANONICAL_VERBS, 0.5, (0.45, 0.20, 0.06),
                        noise_adjacent=0.42, noise_uniform=0.20,
                        format_prob=(0.20, 0.12, 0.06))),
    # curated instruction datasets — degenerate (GPT brevity constraint)
    "alpaca": CorpusProfile(
        name="alpaca", published_total=52002,
        published_counts=(49284, 2056, 4),
        style=_mk_style(_CANONICAL_VERBS, 0.8, (0.30, 0.18, 0.12),
                        noise_adjacent=0.35, noise_uniform=0.15),
        response_long_mean=900.0),
    "codealpaca": CorpusProfile(
        name="codealpaca", published_total=20022,
        published_counts=(19457, 379, 3),
        style=_mk_style(_CANONICAL_VERBS, 0.8, (0.85, 0.80, 0.75),
                        noise_adjacent=0.35, noise_uniform=0.15),
        response_long_mean=900.0),
    # test-only
    "dolly": CorpusProfile(
        name="dolly", published_total=15011,
        published_counts=(13000, 1900, 88),
        style=_mk_style(_DOLLY_VERBS, 0.7, (0.25, 0.15, 0.10),
                        noise_adjacent=0.42, noise_uniform=0.22)),
    "cnn_dailymail": CorpusProfile(
        name="cnn_dailymail", published_total=11490,
        published_counts=(11441, 48, 1),
        style=_mk_style(_CANONICAL_VERBS, 0.8, (0.05, 0.05, 0.05),
                        noise_adjacent=0.30, noise_uniform=0.15),
        response_long_mean=850.0),
}


@dataclass
class Dataset:
    name: str
    prompts: List[str]
    lengths: np.ndarray      # true response token counts
    classes: np.ndarray      # derived 3-class labels

    def __len__(self):
        return len(self.prompts)


def _sample_verb(rng, style: LexStyle, klass: int) -> str:
    # small chance of an out-of-table verb ("other" bucket)
    if rng.random() < 0.08:
        return rng.choice(["craft", "outline", "ponder", "sketch", "assess"])
    # verb_strength < 1 decouples verbs from class (oasst1: verbs ~ noise)
    if rng.random() > style.verb_strength:
        return INSTRUCTION_VERBS[int(rng.integers(0, len(INSTRUCTION_VERBS)))]
    pairs = style.verb_affinity[klass]
    idx = np.array([p[0] for p in pairs])
    w = np.array([p[1] for p in pairs])
    return INSTRUCTION_VERBS[rng.choice(idx, p=w / w.sum())]


def _leak_class(rng, klass: int, style: LexStyle) -> int:
    u = rng.random()
    if u < style.noise_uniform:
        return int(rng.integers(0, 3))
    if u < style.noise_uniform + style.noise_adjacent:
        if klass == MEDIUM:
            return SHORT if rng.random() < 0.5 else LONG
        return MEDIUM  # short/long leak to the boundary class
    return klass


def _gen_prompt(rng, style: LexStyle, klass: int) -> str:
    fk = _leak_class(rng, klass, style)
    verb = _sample_verb(rng, style, fk)
    topic = rng.choice(_TOPICS)
    parts = [verb.capitalize()]
    if rng.random() < style.code_prob[fk]:
        parts.append(rng.choice(_CODE_SNIPPETS) + " for")
    parts.append(topic)
    if rng.random() < style.format_prob[fk]:
        parts.append(rng.choice(_FORMAT_ASKS))
    if rng.random() < style.constraint_prob[fk]:
        parts.append(rng.choice(_LONG_CONSTRAINTS if fk == LONG
                                else _SHORT_CONSTRAINTS))
    n_clauses = rng.poisson(style.clause_rate[fk])
    for _ in range(min(n_clauses, 3)):
        parts.append(rng.choice(_CLAUSES))
    # pad with fillers to reach the class-dependent word-length target
    target = max(4, int(rng.normal(style.words_mean[fk], style.words_std[fk])))
    text = " ".join(parts)
    words = text.split()
    while len(words) < target:
        words.append(rng.choice(_FILLERS))
    text = " ".join(words)
    if rng.random() < style.question_prob[fk]:
        text = text + "?"
    return text


def _gen_length(rng, profile: CorpusProfile, klass: int) -> int:
    if klass == SHORT:
        return int(np.clip(rng.lognormal(3.7, 0.8), 1, 199))
    if klass == MEDIUM:
        return int(rng.integers(200, 800))
    mu = np.log(profile.response_long_mean)
    return int(np.clip(rng.lognormal(mu, 0.45), 800, 8000))


def sample_dataset(profile_name: str, n: int, seed: int = 0,
                   balanced: bool = False) -> Dataset:
    """Draw n examples from a profile (balanced => n/3 per class)."""
    profile = PROFILES[profile_name]
    rng = np.random.default_rng(seed)
    if balanced:
        per = n // 3
        classes = np.repeat(np.arange(3), per)
        n = 3 * per
    else:
        classes = rng.choice(3, size=n, p=profile.class_probs)
    prompts, lengths = [], np.zeros(n, np.int64)
    for i, k in enumerate(classes):
        prompts.append(_gen_prompt(rng, profile.style, int(k)))
        lengths[i] = _gen_length(rng, profile, int(k))
    perm = rng.permutation(n)
    return Dataset(name=profile_name,
                   prompts=[prompts[j] for j in perm],
                   lengths=lengths[perm], classes=classes[perm])
